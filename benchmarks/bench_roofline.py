"""§Roofline: three-term analysis per (arch × shape × mesh) cell.

    compute term    = HLO_dot_FLOPs(dev)        / peak_FLOP/s
    memory term     = HBM_traffic_estimate(dev) / HBM_bw
    collective term = HLO_collective_bytes(dev) / link_bw

Sources & methodology (see EXPERIMENTS.md §Roofline for the full discussion):
  * FLOPs and collective bytes come from the trip-count-corrected static
    analysis of the compiled per-device HLO (launch/hlo_cost.py) — XLA's own
    cost_analysis counts while bodies once (calibrated in tests/test_hlo_cost).
  * Raw HLO "bytes accessed" counts loop-carried buffers once per iteration,
    but on TPU those live in VMEM (scan state, flash accumulators), so it
    overestimates HBM traffic by orders of magnitude for scanned models.
    The memory term therefore uses an explicit HBM-traffic model:
        train:   3*W + 2*opt_mem + 3*A + 2*V      (weights fwd/bwd/update, opt r/w,
                                             carries save+2xread, logits w/r)
        prefill: W + 2*A + V + KV_write
        decode:  W + KV_read (+state)        (weights + full cache per token)
    with W=param bytes/dev, opt_mem=opt bytes/dev, A=saved activation carries/dev,
    V=logit bytes/dev, all under the recorded shardings.
  * MODEL_FLOPS = 2*N_active*tokens*(3 if train) + attention quadratic term
    (0.5 causal) — at 32k context attention dominates 6ND ~20x, so omitting
    it would misread every prefill cell.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

PEAK_FLOPS = 197e12        # bf16, TPU v5e per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link


def _tokens(shape: str) -> int:
    return {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
            "decode_32k": 128, "long_500k": 1}[shape]


def _seq(shape: str) -> int:
    return {"train_4k": 4096, "prefill_32k": 32768,
            "decode_32k": 32768, "long_500k": 524288}[shape]


def _batch(shape: str) -> int:
    return {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
            "long_500k": 1}[shape]


def _cfg(arch: str):
    from repro.configs import get_config
    return get_config(arch)


def attention_model_flops(cfg, shape: str, train: bool) -> float:
    """Quadratic attention FLOPs (query-key + prob-value), causal 0.5."""
    S, B = _seq(shape), _batch(shape)
    if cfg.rwkv is not None:
        # linear recurrence: D^2 per head per *processed token*
        d = cfg.d_model
        hd = cfg.rwkv.head_dim
        toks = 1 if shape.startswith(("decode", "long")) else S
        f = 4.0 * B * toks * d * hd * cfg.n_layers
        return f * (3 if train else 1)
    n_attn_layers = cfg.n_layers
    window = cfg.sliding_window or 0
    if cfg.family == "hybrid":
        n_attn_layers = sum(1 for i in range(cfg.n_layers)
                            if cfg.shared_attn_every and
                            (i + 1) % cfg.shared_attn_every == 0)
        # ssm layers: chunked SSD ~ linear
    if cfg.mla:
        qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        vd = cfg.mla.v_head_dim
    else:
        qk = vd = cfg.hd()
    H = cfg.n_heads
    if shape.startswith("decode") or shape == "long_500k":
        kv = min(S, window) if window else S
        f = 2.0 * B * H * kv * (qk + vd)
        return f * n_attn_layers
    kv_extent = min(S, window) if window else S
    f = 2.0 * B * H * S * kv_extent * (qk + vd) * 0.5
    return f * n_attn_layers * (3 if train else 1)


def hbm_traffic(rec: dict, cfg) -> float:
    """Per-device HBM bytes for one step (model documented above)."""
    shape = rec["shape"]
    n_dev = rec["n_devices"]
    W = cfg.n_params() * 2.0 / n_dev
    opt_b = rec.get("opt_bits", 32)
    opt_mem = cfg.n_params() * (2.0 if opt_b == 8 else 8.0) / n_dev
    S, B = _seq(shape), _batch(shape)
    A = cfg.n_layers * B * min(S, 2 ** 31) * cfg.d_model * 2.0 / n_dev
    V = B * (S if not shape.startswith(("decode", "long")) else 1) * cfg.vocab * 2.0 / n_dev
    kind = ("train" if shape.startswith("train") else
            "decode" if shape.startswith(("decode", "long")) else "prefill")
    if kind == "train":
        return 3 * W + 2 * opt_mem + 3 * A + 2 * V
    if kind == "prefill":
        kv_write = (rec.get("cache_bytes") or 0)
        return W + 2 * A / cfg.n_layers * 4 + V + kv_write
    # decode: weights + the full cache (+recurrent state) per token
    cache = _decode_cache_bytes(cfg, shape) / n_dev
    return W + cache + B * cfg.d_model * cfg.n_layers * 2.0 / n_dev


def _decode_cache_bytes(cfg, shape: str) -> float:
    S, B = _seq(shape), _batch(shape)
    L = cfg.n_layers
    if cfg.rwkv is not None:
        d, hd = cfg.d_model, cfg.rwkv.head_dim
        return L * B * (d // hd) * hd * hd * 4.0
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return L * B * S * per_tok * 2.0
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        ssm_state = (cfg.n_layers * 0.85) * B * (di // s.head_dim) * s.head_dim * s.d_state * 4.0
        W_att = min(S, cfg.sliding_window or S)
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.shared_attn_every and
                     (i + 1) % cfg.shared_attn_every == 0)
        return ssm_state + n_attn * B * W_att * cfg.n_kv_heads * cfg.hd() * 4.0
    return L * B * S * cfg.n_kv_heads * cfg.hd() * 2.0 * 2.0


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = _cfg(rec["arch"])
    hc = rec.get("hlo_cost")
    if hc:  # trip-count-corrected static analysis (see launch/hlo_cost.py)
        flops = hc["flops"]
        coll = hc["collective_total"]
    else:
        flops = rec["cost"]["flops"] or 0.0
        coll = rec["collectives"]["total_bytes"]
    mem_bytes = hbm_traffic(rec, cfg)
    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_coll = coll / LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    train = rec["shape"].startswith("train")
    n = cfg.n_active_params()
    model_flops = (2.0 * n * _tokens(rec["shape"]) * (3 if train else 1)
                   + attention_model_flops(cfg, rec["shape"], train))
    hlo_global = flops * rec["n_devices"]
    useful = model_flops / hlo_global if hlo_global else 0.0
    t_star = max(t_compute, t_memory, t_coll)
    frac = (model_flops / rec["n_devices"] / PEAK_FLOPS) / t_star if t_star > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_global": hlo_global,
        "useful_ratio": useful, "roofline_fraction": frac,
        "mem_gib": (rec["memory"].get("peak_bytes") or 0) / 2**30,
    }


def run(emit=print, mesh: str = "pod16x16", tag: str = ""):
    rows = []
    emit("arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
         "dominant,useful_ratio,roofline_frac,mem_GiB")
    suffix = f"__{tag}.json" if tag else ".json"
    for f in sorted(ART.glob(f"*__{mesh}{suffix}")):
        rec = json.loads(f.read_text())
        if tag == "" and rec.get("tag"):
            continue
        a = analyze(rec)
        if a is None:
            st = rec.get("status")
            emit(f"{rec['arch']},{rec['shape']},{rec['mesh']},-,-,-,{st},-,-,-")
            continue
        rows.append(a)
        emit(f"{a['arch']},{a['shape']},{a['mesh']},"
             f"{a['t_compute_s']*1e3:.3f},{a['t_memory_s']*1e3:.3f},"
             f"{a['t_collective_s']*1e3:.3f},{a['dominant']},"
             f"{a['useful_ratio']:.3f},{a['roofline_fraction']:.3f},"
             f"{a['mem_gib']:.2f}")
    return rows
