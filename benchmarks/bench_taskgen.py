"""Task-generation throughput across scanning backends.

The paper's premise (§4, §5.1) is that task-graph *generation* — the
get/put/count loops the compiler emits — must cost like generated C loop
bounds, not like a polyhedral library call.  This benchmark measures exactly
that layer for every backend:

* ``fraction`` — the retained rational reference path,
* ``compiled`` — PR 1's generated integer loop nests (scalar points),
* ``numpy``    — PR 2's vectorized batch enumeration (whole wavefronts as
  index arrays).

Per backend we time producing the graph in its **native representation**:
``materialize()`` (dict-of-tuples adjacency) for the scalar backends and for
the numpy compatibility view, plus ``index_graph()`` (flat index arrays —
what the batched wavefront/executor layers consume) for numpy.  The §4.3
counter sweep and root scan are timed per backend as well (per-task calls
vs array blocks).

Graph equality is asserted, not assumed: task lists, edge lists, pred
counts, root sets, and the index-graph's labels/degrees must be identical
across all backends or the run fails.

Output: one CSV row per (program, backend) with a stable machine-readable
schema — ``rows`` (list of dicts) and geomean summaries are also returned
for the JSON artifact emitted by ``benchmarks/run.py``.
"""
from __future__ import annotations

import time

from repro.core.edt import TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

# (program, tile sizes, params) — sized so the Fraction path takes ~0.1-5 s.
SUITE = [
    ("stencil1d", (4, 4), {"T": 64, "N": 256}),
    ("seidel1d", (4, 4), {"T": 48, "N": 192}),
    ("jacobi2d", (2, 2, 2), {"T": 12, "N": 24}),
    ("heat3d", (2, 2, 2, 2), {"T": 6, "N": 10}),
    ("matmul", (2, 2, 2), {"N": 24}),
    ("trisolv", (2, 2), {"N": 96}),
    ("lu_like", (2, 2, 2), {"N": 20}),
    ("diamond", (1, 1), {"K": 48}),
    ("pipeline", (1, 1), {"M": 64, "S": 24}),
]

SMOKE_SUITE = [
    ("jacobi2d", (2, 2, 2), {"T": 6, "N": 10}),
    ("trisolv", (2, 2), {"N": 32}),
]

BACKENDS = ("fraction", "compiled", "numpy")

CSV_FIELDS = ("program", "backend", "n_tasks", "n_edges", "materialize_ms",
              "enum_ms", "predcount_ms", "roots_ms", "tasks_per_s",
              "edges_per_s")


def _time(fn, reps: int = 1):
    """Best-of-``reps`` wall time and the last result.

    Every backend is timed with the same rep count so warm-up or scheduler
    noise cannot bias the reported speedups either way."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return best, out


def _check_identical(ma, mb) -> None:
    assert ma.tasks == mb.tasks, "task sets differ between backends"
    assert ma.succ == mb.succ, "edge lists differ between backends"
    assert ma.pred_n == mb.pred_n, "pred counts differ between backends"


def _geomean(xs):
    g = 1.0
    for x in xs:
        g *= x
    return g ** (1.0 / len(xs)) if xs else 0.0


def _bench_one(name, tiles, params, reps):
    """Rows for one program (one per backend), equality-verified."""
    tilings = {"S": Tiling(tiles)}
    graphs = {b: TiledTaskGraph(PROGRAMS[name](), tilings, backend=b)
              for b in BACKENDS}
    rows = {}
    mats = {}
    counts = {}
    roots = {}
    for b, g in graphs.items():
        t_mat, m = _time(lambda: g.materialize(params), reps)
        mats[b] = m
        tasks = m.tasks
        if b == "numpy":
            # native product: the flat index-array graph
            t_enum, ig = _time(lambda: g.index_graph(params), reps)
            assert ig.n == len(tasks) and ig.n_edges == m.n_edges
            assert ig.tasks == tasks, "index-graph labels differ"
            assert ig.pred_n.tolist() == [m.pred_n[t] for t in tasks], \
                "index-graph degrees differ"
            stmts = list(g.program.statements)
            arrs = g.tasks_arrays(params)
            t_pc, pc = _time(
                lambda: [c for s in stmts
                         for c in g.pred_count_block(s, arrs[s], params)],
                reps)
            counts[b] = [int(c) for c in pc]
        else:
            t_enum = t_mat
            t_pc, pc = _time(
                lambda: [g.pred_count(t, params) for t in tasks], reps)
            counts[b] = pc
        t_roots, rt = _time(lambda: list(g.roots(params)), reps)
        roots[b] = rt
        n, e = len(tasks), m.n_edges
        rows[b] = {
            "program": name,
            "backend": b,
            "n_tasks": n,
            "n_edges": e,
            "materialize_ms": round(t_mat * 1e3, 3),
            "enum_ms": round(t_enum * 1e3, 3),
            "predcount_ms": round(t_pc * 1e3, 3),
            "roots_ms": round(t_roots * 1e3, 3),
            "tasks_per_s": round(n / max(t_enum, 1e-9)),
            "edges_per_s": round(e / max(t_enum, 1e-9)),
        }
    for b in ("compiled", "numpy"):
        _check_identical(mats["fraction"], mats[b])
        assert counts["fraction"] == counts[b], \
            f"pred counts differ (fraction vs {b})"
        assert roots["fraction"] == roots[b], \
            f"root sets differ (fraction vs {b})"
    return [rows[b] for b in BACKENDS]


def run(emit=print, smoke: bool = False):
    suite = SMOKE_SUITE if smoke else SUITE
    reps = 1 if smoke else 3
    emit(",".join(CSV_FIELDS))
    rows = []
    for name, tiles, params in suite:
        prog_rows = _bench_one(name, tiles, params, reps)
        rows.extend(prog_rows)
        for r in prog_rows:
            emit(",".join(str(r[f]) for f in CSV_FIELDS), flush=True)
    by = {(r["program"], r["backend"]): r for r in rows}
    progs = [s[0] for s in suite]
    enum_sp = [by[p, "compiled"]["materialize_ms"]
               / max(by[p, "numpy"]["enum_ms"], 1e-6) for p in progs]
    mat_sp = [by[p, "compiled"]["materialize_ms"]
              / max(by[p, "numpy"]["materialize_ms"], 1e-6) for p in progs]
    frac_sp = [by[p, "fraction"]["materialize_ms"]
               / max(by[p, "compiled"]["materialize_ms"], 1e-6) for p in progs]
    pc_sp = [by[p, "compiled"]["predcount_ms"]
             / max(by[p, "numpy"]["predcount_ms"], 1e-6) for p in progs]
    roots_sp = [by[p, "compiled"]["roots_ms"]
                / max(by[p, "numpy"]["roots_ms"], 1e-6) for p in progs]
    geo = {
        "numpy_enum_over_compiled": round(_geomean(enum_sp), 2),
        "numpy_materialize_over_compiled": round(_geomean(mat_sp), 2),
        "compiled_over_fraction": round(_geomean(frac_sp), 2),
        "numpy_predcount_over_compiled": round(_geomean(pc_sp), 2),
        "numpy_roots_over_compiled": round(_geomean(roots_sp), 2),
    }
    emit(f"# geomean enumeration speedup (numpy index arrays over compiled "
         f"materialize): {geo['numpy_enum_over_compiled']:.1f}x over "
         f"{len(progs)} programs (graphs verified identical)")
    emit(f"# geomean dict-view materialize speedup (numpy over compiled): "
         f"{geo['numpy_materialize_over_compiled']:.1f}x; compiled over "
         f"fraction: {geo['compiled_over_fraction']:.1f}x")
    emit(f"# geomean pred_count block speedup: "
         f"{geo['numpy_predcount_over_compiled']:.1f}x; roots: "
         f"{geo['numpy_roots_over_compiled']:.1f}x")
    return {"schema_version": 1, "rows": rows, "geomean": geo}


if __name__ == "__main__":
    run()
