"""Task-generation throughput: compiled vs Fraction scanning backend.

The paper's premise (§4, §5.1) is that task-graph *generation* — the
get/put/count loops the compiler emits — must cost like generated C loop
bounds, not like a polyhedral library call.  This benchmark measures exactly
that layer: ``TiledTaskGraph.materialize`` (task creation + put loops),
``pred_count`` sweeps (the counted/autodec master's §4.3 work), and ``roots``
enumeration, under the compiled integer backend vs the retained Fraction
reference path.  Graph equality is asserted, not assumed: the speedup only
counts if task sets, edge lists, and pred counts are identical.

Reported per program: tasks/sec and edges/sec (compiled), and the
compiled-over-Fraction speedup per phase.
"""
from __future__ import annotations

import time

from repro.core.edt import TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

# (program, tile sizes, params) — sized so the Fraction path takes ~0.1-5 s.
SUITE = [
    ("stencil1d", (4, 4), {"T": 64, "N": 256}),
    ("seidel1d", (4, 4), {"T": 48, "N": 192}),
    ("jacobi2d", (2, 2, 2), {"T": 12, "N": 24}),
    ("heat3d", (2, 2, 2, 2), {"T": 6, "N": 10}),
    ("matmul", (2, 2, 2), {"N": 24}),
    ("trisolv", (2, 2), {"N": 96}),
    ("lu_like", (2, 2, 2), {"N": 20}),
    ("diamond", (1, 1), {"K": 48}),
    ("pipeline", (1, 1), {"M": 64, "S": 24}),
]

SMOKE_SUITE = [
    ("jacobi2d", (2, 2, 2), {"T": 6, "N": 10}),
    ("trisolv", (2, 2), {"N": 32}),
]


def _time(fn, reps: int = 1):
    """Best-of-``reps`` wall time and the last result.

    Both backends are always timed with the same rep count so warm-up or
    scheduler noise cannot bias the reported speedup either way."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return best, out


def _check_identical(mc, mf) -> None:
    assert mc.tasks == mf.tasks, "task sets differ between backends"
    assert mc.succ == mf.succ, "edge lists differ between backends"
    assert mc.pred_n == mf.pred_n, "pred counts differ between backends"


def run(emit=print, smoke: bool = False):
    suite = SMOKE_SUITE if smoke else SUITE
    reps = 1 if smoke else 3
    emit("program,n_tasks,n_edges,mat_compiled_ms,mat_fraction_ms,"
         "mat_speedup,tasks_per_s,edges_per_s,predcount_speedup,roots_speedup")
    speedups = []
    for name, tiles, params in suite:
        tilings = {"S": Tiling(tiles)}
        gc = TiledTaskGraph(PROGRAMS[name](), tilings)
        gf = TiledTaskGraph(PROGRAMS[name](), tilings, backend="fraction")

        t_c, mc = _time(lambda: gc.materialize(params), reps)
        t_f, mf = _time(lambda: gf.materialize(params), reps)
        _check_identical(mc, mf)

        # §4.3 counter sweep (what the counted/autodec master executes)
        tasks = mc.tasks
        t_pc_c, counts_c = _time(
            lambda: [gc.pred_count(t, params) for t in tasks], reps)
        t_pc_f, counts_f = _time(
            lambda: [gf.pred_count(t, params) for t in tasks], reps)
        assert counts_c == counts_f, "pred counts differ between backends"

        t_r_c, roots_c = _time(lambda: list(gc.roots(params)), reps)
        t_r_f, roots_f = _time(lambda: list(gf.roots(params)), reps)
        assert roots_c == roots_f, "root sets differ between backends"

        n, e = len(tasks), mc.n_edges
        sp = t_f / max(t_c, 1e-9)
        speedups.append(sp)
        emit(f"{name},{n},{e},{t_c*1e3:.2f},{t_f*1e3:.2f},{sp:.1f},"
             f"{n/max(t_c,1e-9):.0f},{e/max(t_c,1e-9):.0f},"
             f"{t_pc_f/max(t_pc_c,1e-9):.1f},{t_r_f/max(t_r_c,1e-9):.1f}",
             flush=True)
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    emit(f"# geomean materialize speedup: {geo:.1f}x over {len(speedups)} "
         f"programs (graphs verified identical)")
    return speedups


if __name__ == "__main__":
    run()
