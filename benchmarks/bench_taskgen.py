"""Task-generation throughput across scanning backends and shard counts.

The paper's premise (§4, §5.1) is that task-graph *generation* — the
get/put/count loops the compiler emits — must cost like generated C loop
bounds, not like a polyhedral library call.  This benchmark measures exactly
that layer for every backend:

* ``fraction`` — the retained rational reference path,
* ``compiled`` — PR 1's generated integer loop nests (scalar points),
* ``numpy``    — PR 2's vectorized batch enumeration (whole wavefronts as
  index arrays),
* ``numpy`` with ``shards=n`` — the sharded materialization engine
  (:mod:`repro.core.edt.shard`): scans fan out across a process pool and
  stream into shared-memory index arrays.

Per backend we time producing the graph in its **native representation**:
``materialize()`` (dict-of-tuples adjacency) for the scalar backends and for
the numpy compatibility view, plus ``index_graph()`` (flat index arrays —
what the batched wavefront/executor layers consume) for numpy and the
sharded rows.  The §4.3 counter sweep and root scan are timed per backend
as well (per-task calls vs array blocks vs merged-array bincount).

Graph equality is asserted, not assumed: task lists, edge lists, pred
counts, root sets, and the index-graph's labels/degrees must be identical
across all backends *and all shard counts* or the run fails.

``run(scale=True)`` (the default outside smoke mode) additionally
materializes ≥1M-task graphs end-to-end and reports the speedup curve
across shard counts, with byte-identical results verified against the
single-process arrays.

Output: one CSV row per (program, backend, shards) with a stable
machine-readable schema — ``rows`` / ``shard_scale`` (lists of dicts) and
geomean summaries are also returned for the JSON artifact emitted by
``benchmarks/run.py``.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np

from repro.core.edt import ExecutionConfig, TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

# (program, tile sizes, params) — sized so the Fraction path takes ~0.1-5 s.
SUITE = [
    ("stencil1d", (4, 4), {"T": 64, "N": 256}),
    ("seidel1d", (4, 4), {"T": 48, "N": 192}),
    ("jacobi2d", (2, 2, 2), {"T": 12, "N": 24}),
    ("heat3d", (2, 2, 2, 2), {"T": 6, "N": 10}),
    ("matmul", (2, 2, 2), {"N": 24}),
    ("trisolv", (2, 2), {"N": 96}),
    ("lu_like", (2, 2, 2), {"N": 20}),
    ("diamond", (1, 1), {"K": 48}),
    ("pipeline", (1, 1), {"M": 64, "S": 24}),
]

SMOKE_SUITE = [
    ("jacobi2d", (2, 2, 2), {"T": 6, "N": 10}),
    ("trisolv", (2, 2), {"N": 32}),
]

BACKENDS = ("fraction", "compiled", "numpy")
SHARD_COUNTS = (2, 4)

# ≥1M-task graphs for the end-to-end scale curve.  jacobi2d's ragged
# 6-dim joint scans are compute-bound (sharding wins); diamond's dense box
# is bandwidth-bound (an honest overhead floor on few-core hosts).
SCALE_SUITE = [
    ("jacobi2d", (2, 2, 2), {"T": 32, "N": 512}),
    ("diamond", (1, 1), {"K": 1024}),
]
SMOKE_SCALE_SUITE = [
    ("jacobi2d", (2, 2, 2), {"T": 8, "N": 64}),
]
SCALE_SHARDS = (1, 2, 4)

CSV_FIELDS = ("program", "backend", "shards", "n_tasks", "n_edges",
              "materialize_ms", "enum_ms", "predcount_ms", "roots_ms",
              "tasks_per_s", "edges_per_s")


def _time(fn, reps: int = 1):
    """Best-of-``reps`` wall time and the last result.

    Every backend is timed with the same rep count so warm-up or scheduler
    noise cannot bias the reported speedups either way."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return best, out


def _check_identical(ma, mb) -> None:
    assert ma.tasks == mb.tasks, "task sets differ between backends"
    assert ma.succ == mb.succ, "edge lists differ between backends"
    assert ma.pred_n == mb.pred_n, "pred counts differ between backends"


def _check_ig_identical(a, b) -> None:
    """Byte-identical flat graphs: blocks, edge columns, in-degrees."""
    assert a.n == b.n, "task counts differ"
    assert np.array_equal(a.edge_src, b.edge_src), "edge sources differ"
    assert np.array_equal(a.edge_tgt, b.edge_tgt), "edge targets differ"
    assert np.array_equal(a.pred_n, b.pred_n), "in-degrees differ"
    for (na, xa), (nb, xb) in zip(a.stmt_blocks, b.stmt_blocks):
        assert na == nb and np.array_equal(xa, xb), "stmt blocks differ"


def _geomean(xs):
    g = 1.0
    for x in xs:
        g *= x
    return g ** (1.0 / len(xs)) if xs else 0.0


def _row(name, backend, shards, n, e, t_mat, t_enum, t_pc, t_roots):
    return {
        "program": name,
        "backend": backend,
        "shards": shards,
        "n_tasks": n,
        "n_edges": e,
        "materialize_ms": round(t_mat * 1e3, 3),
        "enum_ms": round(t_enum * 1e3, 3),
        "predcount_ms": round(t_pc * 1e3, 3),
        "roots_ms": round(t_roots * 1e3, 3),
        "tasks_per_s": round(n / max(t_enum, 1e-9)),
        "edges_per_s": round(e / max(t_enum, 1e-9)),
    }


def _bench_one(name, tiles, params, reps, pool):
    """Rows for one program (one per backend + shard count), verified."""
    tilings = {"S": Tiling(tiles)}
    graphs = {b: TiledTaskGraph(PROGRAMS[name](), tilings, backend=b)
              for b in BACKENDS}
    rows = []
    mats = {}
    counts = {}
    roots = {}
    igs = {}
    for b, g in graphs.items():
        t_mat, m = _time(lambda: g.materialize(params), reps)
        mats[b] = m
        tasks = m.tasks
        if b == "numpy":
            # native product: the flat index-array graph
            t_enum, ig = _time(lambda: g.index_graph(params), reps)
            igs[1] = ig
            assert ig.n == len(tasks) and ig.n_edges == m.n_edges
            assert ig.tasks == tasks, "index-graph labels differ"
            assert ig.pred_n.tolist() == [m.pred_n[t] for t in tasks], "index-graph degrees differ"
            stmts = list(g.program.statements)
            arrs = g.tasks_arrays(params)
            t_pc, pc = _time(
                lambda: [c for s in stmts
                         for c in g.pred_count_block(s, arrs[s], params)],
                reps)
            counts[b] = [int(c) for c in pc]
        else:
            t_enum = t_mat
            t_pc, pc = _time(
                lambda: [g.pred_count(t, params) for t in tasks], reps)
            counts[b] = pc
        t_roots, rt = _time(lambda: list(g.roots(params)), reps)
        roots[b] = rt
        rows.append(_row(name, b, 1, len(tasks), m.n_edges,
                         t_mat, t_enum, t_pc, t_roots))
    for b in ("compiled", "numpy"):
        _check_identical(mats["fraction"], mats[b])
        assert counts["fraction"] == counts[b], f"pred counts differ (fraction vs {b})"
        assert roots["fraction"] == roots[b], f"root sets differ (fraction vs {b})"
    # sharded rows: the same graph through the process-pool engine,
    # byte-identical to the single-process arrays (asserted).
    g = graphs["numpy"]
    n, e = len(mats["numpy"].tasks), mats["numpy"].n_edges
    for s in SHARD_COUNTS:
        cfg = ExecutionConfig(shards=s, pool=pool)
        t_mat, m_s = _time(
            lambda: g.materialize(params, config=cfg), reps)
        _check_identical(mats["fraction"], m_s)
        t_enum, ig_s = _time(
            lambda: g.index_graph(params, config=cfg), reps)
        _check_ig_identical(igs[1], ig_s)
        # §4.3 counters / roots from the merged arrays
        t_pc, pn = _time(
            lambda: np.bincount(ig_s.edge_tgt, minlength=ig_s.n), reps)
        assert np.array_equal(pn, igs[1].pred_n)
        t_roots, rt = _time(
            lambda: list(g.roots(params, config=cfg)), reps)
        assert rt == roots["fraction"], f"sharded roots differ (shards={s})"
        rows.append(_row(name, "numpy", s, n, e, t_mat, t_enum, t_pc,
                         t_roots))
    return rows


def shard_scale(emit=print, smoke: bool = False, pool=None, reps: int = 2):
    """≥1M-task end-to-end materialization: the shard-count speedup curve.

    Each graph is generated as flat index arrays (``index_graph``) at every
    shard count and verified byte-identical to the single-process result.
    """
    suite = SMOKE_SCALE_SUITE if smoke else SCALE_SUITE
    rows = []
    own = pool is None
    if own:
        pool = ProcessPoolExecutor(max_workers=os.cpu_count() or 1)
        pool.submit(int, 0).result()
    try:
        for name, tiles, params in suite:
            g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                               backend="numpy")
            base = None
            base_ms = None
            for s in SCALE_SHARDS:
                if s == 1:
                    t, ig = _time(lambda: g.index_graph(params), reps)
                else:
                    cfg = ExecutionConfig(shards=s, pool=pool)
                    g.index_graph(params, config=cfg)  # warm pool
                    t, ig = _time(
                        lambda: g.index_graph(params, config=cfg), reps)
                if base is None:
                    base, base_ms = ig, t * 1e3
                else:
                    _check_ig_identical(base, ig)
                rows.append({
                    "program": name, "shards": s,
                    "n_tasks": ig.n, "n_edges": ig.n_edges,
                    "index_graph_ms": round(t * 1e3, 1),
                    "speedup_vs_1": round(base_ms / (t * 1e3), 2),
                })
                emit(f"# scale {name}: shards={s} tasks={ig.n} "
                     f"edges={ig.n_edges} index_graph={t * 1e3:.0f}ms "
                     f"speedup={rows[-1]['speedup_vs_1']:.2f}x "
                     f"(byte-identical verified)", flush=True)
    finally:
        if own:
            pool.shutdown()
    return rows


def run(emit=print, smoke: bool = False, scale: bool = None):
    suite = SMOKE_SUITE if smoke else SUITE
    reps = 1 if smoke else 3
    if scale is None:
        scale = True
    emit(",".join(CSV_FIELDS))
    rows = []
    pool = ProcessPoolExecutor(
        max_workers=max(1, min(max(SHARD_COUNTS), os.cpu_count() or 1)))
    pool.submit(int, 0).result()   # absorb spawn cost before timing
    try:
        for name, tiles, params in suite:
            prog_rows = _bench_one(name, tiles, params, reps, pool)
            rows.extend(prog_rows)
            for r in prog_rows:
                emit(",".join(str(r[f]) for f in CSV_FIELDS), flush=True)
        scale_rows = shard_scale(emit, smoke=smoke, pool=pool) if scale else []
    finally:
        pool.shutdown()
    by = {(r["program"], r["backend"], r["shards"]): r for r in rows}
    progs = [s[0] for s in suite]
    enum_sp = [by[p, "compiled", 1]["materialize_ms"]
               / max(by[p, "numpy", 1]["enum_ms"], 1e-6) for p in progs]
    mat_sp = [by[p, "compiled", 1]["materialize_ms"]
              / max(by[p, "numpy", 1]["materialize_ms"], 1e-6) for p in progs]
    frac_sp = [by[p, "fraction", 1]["materialize_ms"]
               / max(by[p, "compiled", 1]["materialize_ms"], 1e-6)
               for p in progs]
    pc_sp = [by[p, "compiled", 1]["predcount_ms"]
             / max(by[p, "numpy", 1]["predcount_ms"], 1e-6) for p in progs]
    roots_sp = [by[p, "compiled", 1]["roots_ms"]
                / max(by[p, "numpy", 1]["roots_ms"], 1e-6) for p in progs]
    geo = {
        "numpy_enum_over_compiled": round(_geomean(enum_sp), 2),
        "numpy_materialize_over_compiled": round(_geomean(mat_sp), 2),
        "compiled_over_fraction": round(_geomean(frac_sp), 2),
        "numpy_predcount_over_compiled": round(_geomean(pc_sp), 2),
        "numpy_roots_over_compiled": round(_geomean(roots_sp), 2),
    }
    for s in SHARD_COUNTS:
        sp = [by[p, "numpy", 1]["enum_ms"]
              / max(by[p, "numpy", s]["enum_ms"], 1e-6) for p in progs]
        geo[f"shard{s}_enum_over_numpy"] = round(_geomean(sp), 2)
    emit(f"# geomean enumeration speedup (numpy index arrays over compiled "
         f"materialize): {geo['numpy_enum_over_compiled']:.1f}x over "
         f"{len(progs)} programs (graphs verified identical)")
    emit(f"# geomean dict-view materialize speedup (numpy over compiled): "
         f"{geo['numpy_materialize_over_compiled']:.1f}x; compiled over "
         f"fraction: {geo['compiled_over_fraction']:.1f}x")
    emit(f"# geomean pred_count block speedup: "
         f"{geo['numpy_predcount_over_compiled']:.1f}x; roots: "
         f"{geo['numpy_roots_over_compiled']:.1f}x")
    emit(f"# sharded enumeration vs single-process numpy (small suite — "
         f"pool overhead dominates; see the scale rows for the real curve): "
         + ", ".join(f"{s} shards {geo[f'shard{s}_enum_over_numpy']:.2f}x"
                     for s in SHARD_COUNTS))
    return {"schema_version": 2, "rows": rows, "geomean": geo,
            "shard_scale": scale_rows,
            "host_cpus": os.cpu_count()}


if __name__ == "__main__":
    run()
