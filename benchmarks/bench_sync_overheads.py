"""Paper §2 / Table 2: measured overhead growth per synchronization model.

Runs each model on the diamond DAG (single dominator — the prescribed
model's worst case) at growing task counts and reports the five overhead
counters.  The asymptotic classes of Table 2 appear directly in the growth
columns (n, n^2, r, 1).
"""
from __future__ import annotations

from repro.core.edt import MODELS, TiledTaskGraph, run_model
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

SIZES = (8, 16, 32)
SMOKE_SIZES = (4, 8)


def run(emit=print, smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
    emit("model,K,n_tasks,startup_ops,spatial_peak,inflight_tasks_peak,"
         "inflight_deps_peak,garbage_peak,makespan")
    rows = {}
    for model in MODELS:
        for K in sizes:
            params = {"K": K}
            res = run_model(model, g, params, workers=8)
            s = res.counters.summary()
            n = res.n_tasks
            rows[(model, K)] = s
            emit(f"{model},{K},{n},{s['startup_ops']},{s['spatial_peak']},"
                 f"{s['inflight_tasks_peak']},{s['inflight_deps_peak']},"
                 f"{s['garbage_peak']},{s['makespan']:.2f}")
    # growth factors between the smallest and largest size (tasks scale with
    # the square of the K ratio on the diamond grid)
    lo, hi = sizes[0], sizes[-1]
    ratio = (hi * hi) // (lo * lo)
    for model in MODELS:
        a, b = rows[(model, lo)], rows[(model, hi)]
        emit(f"# {model}: startup x{b['startup_ops']/max(1,a['startup_ops']):.1f}, "
             f"spatial x{b['spatial_peak']/max(1,a['spatial_peak']):.1f}, "
             f"garbage x{b['garbage_peak']/max(1,a['garbage_peak']):.1f} "
             f"(tasks x{ratio})")
    return rows
