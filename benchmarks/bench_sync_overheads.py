"""Paper §2 / Table 2: the synchronization-overhead atlas.

Runs every registered sync model over the atlas workload sweep
(:mod:`repro.core.edt.atlas`: diamond grid, dense-LA Cholesky DAG,
time-skewed stencil, banded fan-out trees x size ladder x task grain),
fits each overhead counter's growth against the candidate asymptotic
classes {1, r, n, e, n^2}, and checks the fits against the paper's
Table-2 bounds.  Where the sweep overlaps the real engines it also
records host-vs-device / distributed crossover points on the counted
model (the one :class:`DeviceExecutor` and ``run_distributed`` execute).

The return value is the schema-v8 ``sync`` section: plain dicts with
string keys throughout — ``benchmarks/run.py`` serializes it verbatim
(no repr fallback) and CI uploads it as the regime-map artifact
(docs/sync_atlas.md).
"""
from __future__ import annotations

from repro.core.edt import atlas


def run(emit=print, smoke: bool = False) -> dict:
    data = atlas.sweep(smoke=smoke, emit=emit)

    # growth footer: factors between the smallest and largest size, with
    # the task/edge/width ratios measured from the graphs themselves
    for g in data["growth"]:
        def fmt(c):
            v = g[c]
            return "born" if v is None else f"x{v:.1f}"
        emit(f"# {g['program']}/{g['model']}: "
             f"startup {fmt('startup_ops')}, spatial {fmt('spatial_peak')}, "
             f"garbage {fmt('garbage_peak')} "
             f"(tasks x{g['task_factor']}, edges x{g['edge_factor']}, "
             f"width x{g['width_factor']})")

    for f in data["fits"]:
        if not f["ok"]:
            emit(f"# FIT MISMATCH {f['program']}/{f['model']}/{f['counter']}: "
                 f"fitted {f['cls']} exceeds expected {f['expected']} "
                 f"(values {f['values']})")
    emit(f"# fits: {len(data['fits'])}, "
         f"failures: {len(data['fit_failures'])}")

    data["crossover"] = atlas.crossover(smoke=smoke, emit=emit)
    return data
