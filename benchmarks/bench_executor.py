"""Paper §5.2: execution-time comparison across synchronization models.

Simulated makespans (deterministic; the container has one core) with a
nontrivial per-master-op cost, matching the paper's observations:
autodec >= tags > counted > prescribed on graphs with dominators, and the
tags-1 spatial cost exploding (their OOM cases) visible in spatial_peak.
Also runs the real-thread autodec runtime for wall-clock sanity.
"""
from __future__ import annotations

import time

from repro.core.edt import (TiledTaskGraph, run_graph_threaded, run_model)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

CASES = [
    ("diamond", {"S": Tiling((1, 1))}, {"K": 24}),
    ("trisolv", {"S": Tiling((2, 2))}, {"N": 36}),
    ("stencil1d", {"S": Tiling((4, 8))}, {"T": 24, "N": 96}),
    ("pipeline", {"S": Tiling((1, 1))}, {"M": 24, "S": 8}),
]
SMOKE_CASES = [
    ("diamond", {"S": Tiling((1, 1))}, {"K": 10}),
    ("pipeline", {"S": Tiling((1, 1))}, {"M": 8, "S": 4}),
]
MODELS_ = ("prescribed", "tags1", "tags2", "counted", "autodec")


def run(emit=print, smoke: bool = False):
    cases = SMOKE_CASES if smoke else CASES
    emit("program,model,n_tasks,makespan,startup_ops,spatial_peak")
    out = {}
    for name, tiling, params in cases:
        g = TiledTaskGraph(PROGRAMS[name](), tiling)
        for model in MODELS_:
            res = run_model(model, g, params, workers=8, setup_cost=0.05)
            s = res.counters.summary()
            out[(name, model)] = s["makespan"]
            emit(f"{name},{model},{res.n_tasks},{s['makespan']:.2f},"
                 f"{s['startup_ops']},{s['spatial_peak']}")
        t0 = time.perf_counter()
        run_graph_threaded(g, params, workers=4)
        emit(f"{name},autodec_threads_wallclock,-,{time.perf_counter()-t0:.3f}s,-,-")
    for name, *_ in cases:
        sp = out[(name, "prescribed")] / out[(name, "autodec")]
        emit(f"# {name}: autodec vs prescribed makespan speedup {sp:.2f}x")
    return out
