"""Paper §5.2: execution-time comparison across synchronization models,
plus the host-vs-device dispatch benchmark for wavefront schedules.

Part 1 (``models``) — simulated makespans (deterministic; the container
has two cores) with a nontrivial per-master-op cost, matching the paper's
observations: autodec >= tags > counted > prescribed on graphs with
dominators, and the tags-1 spatial cost exploding (their OOM cases)
visible in spatial_peak.  Also runs the real-thread autodec runtime for
wall-clock sanity.

Part 2 (``dispatch``) — what does it cost *per task* to drive a synthesized
wavefront schedule?  Three paths over the same index graph:

* ``host``            — ``simulate_indexed`` feeding the instrumented Sim
                        level by level (``Sim.make_ready_ids``: deque +
                        heapq per task, no per-task closures),
* ``device_replay``   — :class:`~repro.core.edt.DeviceExecutor` replay
                        sweep: one ``fori_loop`` over levels, counters
                        decremented and validated on the jax layer,
                        O(V+E) total,
* ``device_discover`` — the self-leveling counted sweep (frontiers derived
                        from counters alone, O(depth·(V+E))); skipped on
                        the ≥1M-task case where the dense-frontier cost is
                        the documented tradeoff.

Frontier identity across paths is asserted, not assumed.  The full run
includes a ≥1M-task jacobi2d case (the acceptance graph of
docs/device_exec.md); smoke keeps the same row schema on a small case.
Rows land in the CI JSON artifact via ``benchmarks/run.py --json``
(schema v3).
"""
from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np

from repro.core.edt import (DeviceExecutor, ExecutionConfig, TiledTaskGraph,
                            run_graph_threaded, run_model, simulate_indexed,
                            synthesize_indexed)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

CASES = [
    ("diamond", {"S": Tiling((1, 1))}, {"K": 24}),
    ("trisolv", {"S": Tiling((2, 2))}, {"N": 36}),
    ("stencil1d", {"S": Tiling((4, 8))}, {"T": 24, "N": 96}),
    ("pipeline", {"S": Tiling((1, 1))}, {"M": 24, "S": 8}),
]
SMOKE_CASES = [
    ("diamond", {"S": Tiling((1, 1))}, {"K": 10}),
    ("pipeline", {"S": Tiling((1, 1))}, {"M": 8, "S": 4}),
]
MODELS_ = ("prescribed", "tags1", "tags2", "counted", "autodec")

# (program, tile sizes, params, shards, run_discover) — the dispatch suite.
# The last full case is the ≥1M-task acceptance graph; discover mode is
# priced on the mid case only (its O(depth·E) cost at 1M is the tradeoff
# docs/device_exec.md documents, not a number worth re-measuring per PR).
DISPATCH_CASES = [
    ("jacobi2d", (2, 2, 2), {"T": 16, "N": 128}, 1, True),
    ("jacobi2d", (2, 2, 2), {"T": 32, "N": 512}, 4, False),
]
SMOKE_DISPATCH_CASES = [
    ("jacobi2d", (2, 2, 2), {"T": 8, "N": 64}, 2, True),
]


def _models(emit, cases):
    emit("program,model,n_tasks,makespan,startup_ops,spatial_peak")
    rows = []
    makespans = {}
    for name, tiling, params in cases:
        g = TiledTaskGraph(PROGRAMS[name](), tiling)
        for model in MODELS_:
            res = run_model(model, g, params, workers=8, setup_cost=0.05)
            s = res.counters.summary()
            makespans[(name, model)] = s["makespan"]
            rows.append({"program": name, "model": model,
                         "n_tasks": res.n_tasks,
                         "makespan": s["makespan"],
                         "startup_ops": s["startup_ops"],
                         "spatial_peak": s["spatial_peak"]})
            emit(f"{name},{model},{res.n_tasks},{s['makespan']:.2f},"
                 f"{s['startup_ops']},{s['spatial_peak']}")
        t0 = time.perf_counter()
        run_graph_threaded(g, params, workers=4)
        emit(f"{name},autodec_threads_wallclock,-,{time.perf_counter()-t0:.3f}s,-,-")
    for name, *_ in cases:
        sp = makespans[(name, "prescribed")] / makespans[(name, "autodec")]
        emit(f"# {name}: autodec vs prescribed makespan speedup {sp:.2f}x")
    return rows


def _verified(run, sched) -> bool:
    return (len(run.levels) == sched.depth
            and all(np.array_equal(a, b)
                    for a, b in zip(run.levels, sched.levels)))


def _dispatch(emit, cases, pool=None):
    emit("program,path,shards,tasks,edges,depth,seconds,per_task_us,verified")
    rows = []

    def row(name, path, shards, ig, sched, seconds, verified, **extra):
        r = {"program": name, "path": path, "shards": shards,
             "tasks": ig.n, "edges": ig.n_edges, "depth": sched.depth,
             "seconds": round(seconds, 4),
             "per_task_us": round(1e6 * seconds / max(1, ig.n), 3),
             "verified": bool(verified), **extra}
        rows.append(r)
        emit(f"{name},{path},{shards},{ig.n},{ig.n_edges},{sched.depth},"
             f"{r['seconds']},{r['per_task_us']},{r['verified']}")
        return r

    for name, tiles, params, shards, discover in cases:
        g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                           backend="numpy")
        t0 = time.perf_counter()
        ig, sched = synthesize_indexed(g, params, config=ExecutionConfig(
            shards=shards if shards > 1 else None, pool=pool))
        emit(f"# {name}: generation+leveling {time.perf_counter()-t0:.2f}s "
             f"({ig.n} tasks, {ig.n_edges} edges, depth {sched.depth})")

        t0 = time.perf_counter()
        sim = simulate_indexed(sched, workers=8)
        host_s = time.perf_counter() - t0
        host_order = np.asarray(sim.exec_order)
        row(name, "host", shards, ig, sched, host_s,
            len(sim.exec_order) == ig.n)

        paths = [("device_replay", dict(schedule=sched))]
        if discover:
            paths.append(("device_discover", {}))
        for path, kw in paths:
            t0 = time.perf_counter()
            dev = DeviceExecutor(ig, **kw)
            pack_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            run = dev.run()                       # cold: includes jit
            first_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            run = dev.run()                       # warm: dispatch cost
            warm_s = time.perf_counter() - t0
            # discover: _verified compares independently computed levels.
            # replay returns the validated input schedule, so the load-
            # bearing checks are run() not raising (on-device counters)
            # and the order cross-check against the host Sim.
            ok = (_verified(run, sched)
                  and np.array_equal(run.exec_order, host_order))
            row(name, path, shards, ig, sched, warm_s, ok,
                pack_seconds=round(pack_s, 4),
                first_seconds=round(first_s, 4))
    return rows


def run(emit=print, smoke: bool = False):
    model_rows = _models(emit, SMOKE_CASES if smoke else CASES)
    dcases = SMOKE_DISPATCH_CASES if smoke else DISPATCH_CASES
    need_pool = any(s > 1 for _, _, _, s, _ in dcases)
    pool = ProcessPoolExecutor(max_workers=2) if need_pool else None
    try:
        dispatch_rows = _dispatch(emit, dcases, pool=pool)
    finally:
        if pool is not None:
            pool.shutdown()
    bad = [r for r in dispatch_rows if not r["verified"]]
    assert not bad, f"dispatch paths diverged: {bad}"
    return {"models": model_rows, "dispatch": dispatch_rows}
