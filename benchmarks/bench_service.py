"""Schedule-service pricing: cold vs warm latency, coalescing, throughput.

The serving posture (``docs/service.md``) promises that once a
``(program, params)`` key is warm, answering "give me the packed
schedule" costs two dictionary probes — no scans, no leveling, no
packing.  This benchmark prices that promise on the flagship ≥1M-task
jacobi2d instance and a small sweep of sizes:

* **cold_ms / warm_ms** — one cold fill (scan + level + pack under the
  session config) vs the warm hit for the same key, per product kind;
* **speedup** — cold/warm ratio (the acceptance floor is ≥50x on the
  flagship, with warm_ms < 1.0);
* **verified** — the warm product is the cold product, by reference
  (which implies byte-identity), and its arrays match an independently
  materialized oracle;
* **service throughput** — concurrent warm requests per second through
  :class:`ScheduleService` (event-loop inline path), plus the coalescing
  stats from a cold concurrent burst.

Rows feed the ``service`` section of ``benchmarks/run.py`` (schema v5).
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core.edt import ScheduleService, Session, TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

#: (label, program, tiles, params) — flagship last so the sweep stays warm.
SUITE = [
    ("small", "jacobi2d", (2, 2, 2), {"T": 4, "N": 48}),
    ("medium", "jacobi2d", (2, 2, 2), {"T": 8, "N": 128}),
    ("flagship", "jacobi2d", (2, 2, 2), {"T": 32, "N": 512}),
]
SMOKE_SUITE = [
    ("small", "jacobi2d", (2, 2, 2), {"T": 4, "N": 48}),
    ("flagship", "jacobi2d", (2, 2, 2), {"T": 6, "N": 96}),
]


def _warm_ms(fn, reps: int = 50) -> float:
    """Best-of-reps latency for an already-warm call, in ms."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _verify(ig, oracle) -> bool:
    return (ig.n == oracle.n
            and np.array_equal(ig.edge_src, oracle.edge_src)
            and np.array_equal(ig.edge_tgt, oracle.edge_tgt)
            and np.array_equal(ig.pred_n, oracle.pred_n))


def _key_rows(session, graph, label, params, emit):
    rows = []
    for kind, call in (
            ("graph", lambda: session.index_graph(graph, params)),
            ("schedule", lambda: session.schedule(graph, params)),
            ("packed", lambda: session.packed(graph, params))):
        t0 = time.perf_counter()
        cold = call()                      # first touch of this product
        cold_ms = (time.perf_counter() - t0) * 1e3
        warm_ms = _warm_ms(call)
        warm = call()
        same = all(a is b for a, b in zip(
            cold if isinstance(cold, tuple) else (cold,),
            warm if isinstance(warm, tuple) else (warm,)))
        speedup = cold_ms / warm_ms if warm_ms > 0 else float("inf")
        ig = session.index_graph(graph, params)
        rows.append({
            "case": label, "kind": kind, "n_tasks": ig.n,
            "n_edges": ig.n_edges, "cold_ms": round(cold_ms, 3),
            "warm_ms": round(warm_ms, 4), "speedup": round(speedup, 1),
            "sub_ms_warm": warm_ms < 1.0, "verified": bool(same),
        })
        emit(f"{label},{kind},{ig.n},{ig.n_edges},{rows[-1]['cold_ms']},"
             f"{rows[-1]['warm_ms']},{rows[-1]['speedup']},"
             f"{rows[-1]['sub_ms_warm']},{same}")
    return rows


def _service_stats(graph, params_list, clients: int) -> dict:
    """Concurrent cold burst (coalescing) + warm throughput."""

    async def drive(service):
        reqs = [p for p in params_list for _ in range(clients)]
        t0 = time.perf_counter()
        await asyncio.gather(
            *(service.schedule(graph, p) for p in reqs))
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        await asyncio.gather(
            *(service.schedule(graph, p) for p in reqs))
        warm_s = time.perf_counter() - t0
        st = service.stats()
        return {
            "keys": len(params_list), "clients": clients,
            "cold_burst_ms": round(cold_s * 1e3, 2),
            "warm_burst_ms": round(warm_s * 1e3, 3),
            "warm_req_per_s": round(len(reqs) / warm_s, 0),
            "cold_fills": st["cold"], "coalesced": st["coalesced"],
            "hit_rate": round(st["hit_rate"], 3),
        }

    service = ScheduleService(config=None)
    try:
        return asyncio.run(drive(service))
    finally:
        service.close()


def run(emit=print, smoke: bool = False):
    suite = SMOKE_SUITE if smoke else SUITE
    emit("# schedule service: cold fill vs warm hit per product kind")
    emit("case,kind,tasks,edges,cold_ms,warm_ms,speedup,sub_ms_warm,verified")
    rows = []
    with Session() as session:
        graph = TiledTaskGraph(PROGRAMS["jacobi2d"](),
                               {"S": Tiling((2, 2, 2))}, backend="numpy")
        for label, _, _, params in suite:
            rows.extend(_key_rows(session, graph, label, params, emit))
        flag_params = suite[-1][3]
        flagship = [r for r in rows
                    if r["case"] == "flagship" and r["kind"] == "packed"][0]
        if not smoke:
            assert flagship["n_tasks"] >= 1_000_000, "flagship shrank"
        # independent oracle for the flagship warm graph (scan from scratch
        # on a fresh graph object — no cache involvement)
        oracle = TiledTaskGraph(
            PROGRAMS["jacobi2d"](), {"S": Tiling((2, 2, 2))},
            backend="numpy").index_graph(flag_params)
        flagship["verified"] = bool(
            flagship["verified"]
            and _verify(session.index_graph(graph, flag_params), oracle))
        emit(f"# flagship packed: {flagship['n_tasks']} tasks, "
             f"cold {flagship['cold_ms']:.0f}ms, warm "
             f"{flagship['warm_ms']:.3f}ms ({flagship['speedup']}x, "
             f"oracle-verified={flagship['verified']})")
    small = [p for _, _, _, p in suite[:-1]] or [suite[-1][3]]
    svc = _service_stats(
        TiledTaskGraph(PROGRAMS["jacobi2d"](), {"S": Tiling((2, 2, 2))},
                       backend="numpy"),
        small, clients=4)
    emit(f"# service: {svc['cold_fills']} cold fills, "
         f"{svc['coalesced']} coalesced, warm {svc['warm_req_per_s']:.0f} "
         f"req/s, hit rate {svc['hit_rate']}")
    return {"rows": rows, "flagship": flagship, "service": svc}


if __name__ == "__main__":
    run()
