"""Recovery overhead: the price of self-healing sharded materialization.

The robustness layer (``docs/robustness.md``) promises that a recoverable
worker fault costs one re-scanned shard job plus backoff — not a restart
of the whole materialization.  This benchmark prices that promise: for
each shard count it times

* the fault-free sharded ``index_graph`` baseline (with the recovery
  machinery *armed* — individual submits, wave timeouts — so the row also
  prices the harness itself against the ``pool.map`` fast path), and
* the same run with one injected recoverable worker crash,

verifying after every run that the produced arrays are byte-identical to
the single-process oracle.  Rows: ``{shards, fault, clean_s, faulty_s,
overhead_ratio, verified}`` for the ``faults`` section of
``benchmarks/run.py`` (schema v4).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.edt import (ExecutionConfig, Fault, FaultPlan,
                            RetryPolicy, TiledTaskGraph, WORKER_CRASH)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

POLICY = RetryPolicy(max_retries=2, base_delay=0.005, timeout=30.0)


def _identical(ig, oracle) -> bool:
    return (ig.n == oracle.n
            and np.array_equal(ig.edge_src, oracle.edge_src)
            and np.array_equal(ig.edge_tgt, oracle.edge_tgt)
            and np.array_equal(ig.pred_n, oracle.pred_n))


def _time_run(g, params, shards, faults):
    t0 = time.time()
    ig = g.index_graph(params, config=ExecutionConfig(
        shards=shards, faults=faults, recovery=POLICY))
    return time.time() - t0, ig


def run(emit=print, smoke: bool = False):
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((4, 4))},
                       backend="numpy")
    params = {"N": 40 if smoke else 120}
    oracle = g.index_graph(params)
    shard_counts = (2,) if smoke else (2, 4)
    emit(f"# recovery overhead: trisolv N={params['N']} "
         f"({oracle.n} tasks), one recoverable crash per faulty run")
    emit("shards,fault,clean_s,faulty_s,overhead_ratio,verified")
    rows = []
    for shards in shard_counts:
        clean_s, ig = _time_run(g, params, shards, None)
        ok = _identical(ig, oracle)
        plan = FaultPlan(faults=(Fault(kind=WORKER_CRASH, round=1, index=0,
                                       times=1),))
        faulty_s, igf = _time_run(g, params, shards, plan)
        ok = ok and _identical(igf, oracle) and bool(plan.fired)
        if not ok:
            raise AssertionError(
                f"recovered graph diverged at shards={shards}")
        ratio = faulty_s / clean_s if clean_s > 0 else float("inf")
        row = {"shards": shards, "fault": "worker_crash@r1",
               "clean_s": round(clean_s, 4), "faulty_s": round(faulty_s, 4),
               "overhead_ratio": round(ratio, 3), "verified": ok}
        rows.append(row)
        emit(f"{shards},{row['fault']},{row['clean_s']},{row['faulty_s']},"
             f"{row['overhead_ratio']},{ok}")
    return {"rows": rows}


if __name__ == "__main__":
    run()
