"""Paper §5.1 / Figure 6: compile-time of compression vs projection.

For every program in the suite, computes each dependence's inter-tile
relation twice — with the paper's compression+inflation method and with the
prior-art lifted Fourier-Motzkin projection — and reports the speedup.
A per-dependence timeout marks projection blowups (the paper's two
timed-out benchmarks).
"""
from __future__ import annotations

import multiprocessing as mp
import time

from repro.core.poly import (Tiling, tile_dependence,
                             tile_dependence_projection)
from repro.core.programs import PROGRAMS

TIMEOUT_S = 120.0

# smoke mode: small, projection-friendly programs run in-process with no
# subprocess or 120 s timeout — a sub-second sanity pass over the section.
SMOKE_SUITE = [
    ("stencil1d", (32, 32)),
    ("diamond", (8, 8)),
]

SUITE = [
    # (program, tile sizes per statement-dim)
    ("stencil1d", (32, 32)),
    ("seidel1d", (16, 16)),
    ("jacobi2d", (8, 8, 8)),
    ("heat3d", (4, 4, 4, 4)),
    ("matmul", (16, 16, 16)),
    ("trisolv", (16, 16)),
    ("lu_like", (8, 8, 8)),
    ("diamond", (8, 8)),
    ("pipeline", (4, 1)),
    ("synthetic5d", (4,) * 5),
    ("synthetic6d", (4,) * 6),
]


def _proj_worker(q, name, dep_idx, tiles):
    prog = PROGRAMS[name]()
    dep = prog.dependences[dep_idx]
    g = Tiling(tuple(tiles))
    t0 = time.perf_counter()
    tile_dependence_projection(dep.delta, dep.src_ndim, g, g)
    q.put(time.perf_counter() - t0)


def _timed_projection(name, dep_idx, tiles) -> tuple[float, bool]:
    """FM projection in a subprocess with a hard kill at TIMEOUT_S.

    Exact Fourier-Motzkin can blow up doubly-exponentially — the paper's own
    experiments had two such timeouts; a hard kill is the honest metric."""
    q: mp.Queue = mp.Queue()
    p = mp.Process(target=_proj_worker, args=(q, name, dep_idx, tiles))
    p.start()
    p.join(TIMEOUT_S)
    if p.is_alive():
        p.terminate()
        p.join()
        return TIMEOUT_S, True
    return q.get(), False


def run(emit=print, smoke: bool = False):
    suite = SMOKE_SUITE if smoke else SUITE
    emit("name,deps,t_compression_ms,t_projection_ms,speedup,note")
    speedups = []
    for name, tiles in suite:
        prog = PROGRAMS[name]()
        g = Tiling(tuple(tiles))
        t_c = t_p = 0.0
        note = ""
        for i, dep in enumerate(prog.dependences):
            t0 = time.perf_counter()
            tile_dependence(dep.delta, dep.src_ndim, g, g, method="inflate")
            t_c += time.perf_counter() - t0
            if smoke:
                t0 = time.perf_counter()
                tile_dependence_projection(dep.delta, dep.src_ndim, g, g)
                dt, timed_out = time.perf_counter() - t0, False
            else:
                dt, timed_out = _timed_projection(name, i, tiles)
            t_p += dt
            if timed_out:
                note = "projection-TIMEOUT(capped)"
        sp = t_p / max(t_c, 1e-9)
        speedups.append(sp)
        emit(f"{name},{len(prog.dependences)},{t_c*1e3:.2f},{t_p*1e3:.2f},"
             f"{sp:.2f},{note}", flush=True)
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    emit(f"# geomean speedup: {geo:.2f}x over {len(speedups)} programs "
         f"(timeouts capped at {TIMEOUT_S:.0f}s)")
    return speedups
