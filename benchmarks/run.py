"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only SECTION] [--smoke]
                                            [--json PATH]

Sections:
  compile   — §5.1 Fig 6: compression vs projection dependence-compute time
  taskgen   — task-generation throughput: fraction vs compiled vs numpy
              scanning backends on materialize / index_graph / pred_count /
              roots (graphs verified identical), plus sharded rows
              (``shards=2/4`` through the process-pool engine) and the
              ≥1M-task shard-scale curve
  sync      — §2 Table 2: overhead counters per synchronization model
  executor  — §5.2: makespan comparison across models (+ threaded autodec)
  roofline  — §Roofline terms from the dry-run artifacts (if present)
  faults    — recovery overhead: fault-free vs one recoverable injected
              worker crash at 2/4 shards, recovered arrays verified
              byte-identical (docs/robustness.md)
  service   — graph-cache serving: cold fill vs warm hit per product kind
              (incl. the ≥1M-task flagship, sub-ms warm target), plus
              ScheduleService coalescing and warm throughput
              (docs/service.md)
  fused     — fused stencil execution: the counted sweep computing real
              tiles, priced per task / per grid point against the
              decrement-only sweep, the host-dispatch NumPy twin, and
              the handwritten jax solve (docs/device_exec.md, "Fused
              execution")
  distributed — rank-partitioned counted-sync execution: per-rank task
              rate and cross-rank message volume on the ≥1M-task
              flagship, inline and process transports, frontiers
              verified byte-identical to the single-host sweep
              (docs/distributed.md)

``--smoke`` runs a fast subset of every section (small suites, no
subprocess projection timeouts) — a correctness-and-entry-point check that
finishes in well under a minute; full runs remain the default.

``--json PATH`` writes a machine-readable result file so CI can upload and
diff perf artifacts across PRs.  Stable schema (version 8):

    {"schema_version": 8, "smoke": bool, "host": {"cpus": int},
     "sections": {name: {"ok": bool, "seconds": float, "data": ...}}}

where ``data`` is the section's own return value (e.g. taskgen emits
``{"rows": [{"program", "backend", "shards", "tasks_per_s", ...}],
"geomean": ..., "shard_scale": [...]}``) and MUST be JSON-serializable:
a section returning anything ``json.dumps`` rejects is recorded with
``ok = False`` and an ``unserializable`` error entry, and the harness
exits non-zero.  (Through v7 such data was silently downgraded to
``repr(...)``, which is how the ``sync`` section shipped opaque for five
schema versions.)  Sharded rows record their shard count in ``shards``;
single-process rows carry ``shards = 1``.

New in v3: the ``executor`` section returns structured data instead of a
repr — ``{"models": [...], "dispatch": [...]}`` where each ``dispatch``
row prices driving one synthesized wavefront schedule through a host or
device path (``path`` in {host, device_replay, device_discover}) with
``seconds`` / ``per_task_us`` / ``verified`` fields, so the artifact
tracks host-vs-device dispatch cost per task across PRs.

New in v4: the ``faults`` section prices the robustness layer — rows
``{shards, fault, clean_s, faulty_s, overhead_ratio, verified}`` compare
fault-free sharded materialization against a run recovering from one
injected worker crash (retry + backoff, byte-identity verified), so the
artifact tracks the recovery tax across PRs.

New in v5: the ``service`` section prices the parametric graph cache —
rows ``{case, kind, cold_ms, warm_ms, speedup, sub_ms_warm, verified}``
per product kind (index graph / schedule / packed device columns), a
``flagship`` row for the ≥1M-task jacobi2d instance (acceptance: warm
hit < 1 ms, ≥50x over cold, arrays verified against an uncached oracle),
and ``service`` stats from a concurrent ScheduleService burst
(cold fills, coalesced requests, warm requests/s, hit rate).

New in v6: the ``fused`` section prices end-to-end device-resident
stencil execution — rows ``{program, path, tasks, points, seconds,
per_task_us, per_point_ns, vs_handwritten, verified}`` per execution path
(``path`` in {handwritten, device_replay, fused, fused_novalidate,
host_dispatch}), numerics verified against the handwritten solve, plus an
``acceptance`` record for the ≥1M-task flagship asserting the fused
per-task time does not exceed the decrement-only sweep.

New in v7: the ``distributed`` section prices the rank-partitioned
runtime — rows ``{program, tasks, ranks, engine, transport, seconds,
per_task_us, msgs, batches, cross_frac, attempts, per_rank, verified}``
where ``per_rank`` breaks out each rank's task count, message traffic and
µs/task, and every row's merged frontiers are verified byte-identical to
the single-host sweep before it is recorded.

New in v8: the ``sync`` section is the Table-2 overhead atlas
(docs/sync_atlas.md) — ``{rows, fits, growth, crossover, ...}`` where
``rows`` are per-(program, model, size, grain) counter measurements over
the atlas workloads, ``fits`` assert each counter's fitted asymptotic
class {1, r, n, e, n^2} against the paper's Table-2 bound, ``growth``
reports lo->hi growth factors with measured task/edge/width ratios, and
``crossover`` prices the counted model through the host simulator, the
device replay sweep, and a two-rank distributed run.  Unserializable
section data now fails the harness instead of degrading to ``repr``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

SCHEMA_VERSION = 8


def encode_section_data(data):
    """Validate section data for the JSON report.

    Returns ``(ok, data)``: the data unchanged when ``json.dumps`` accepts
    it, else ``(False, {"unserializable": ...})`` describing the failure —
    never a silent ``repr`` downgrade (the bug that shipped the ``sync``
    section as an opaque string from schema v2 through v7).
    """
    try:
        json.dumps(data)
    except (TypeError, ValueError) as e:
        return False, {"unserializable": repr(e), "type": type(data).__name__}
    return True, data


def section_registry() -> dict:
    """Name -> run function for every benchmark section (import on call)."""
    from . import (bench_compile, bench_distributed, bench_executor,
                   bench_faults, bench_fused, bench_roofline,
                   bench_service, bench_sync_overheads, bench_taskgen)

    return {
        "compile": bench_compile.run,
        "taskgen": bench_taskgen.run,
        "sync": bench_sync_overheads.run,
        "executor": bench_executor.run,
        "roofline": bench_roofline.run,
        "faults": bench_faults.run,
        "service": bench_service.run,
        "fused": bench_fused.run,
        "distributed": bench_distributed.run,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "compile", "taskgen", "sync", "executor",
                             "roofline", "faults", "service", "fused",
                             "distributed"])
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset of each section (sub-minute total)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args(argv)

    sections = section_registry()
    if args.only:
        sections = {args.only: sections[args.only]}
    rc = 0
    report = {"schema_version": SCHEMA_VERSION, "smoke": bool(args.smoke),
              "host": {"cpus": os.cpu_count()}, "sections": {}}
    for name, fn in sections.items():
        print(f"\n===== bench:{name} =====", flush=True)
        t0 = time.time()
        kw = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kw["smoke"] = True
        ok, data = True, None
        try:
            data = fn(**kw)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"# section {name} failed: {e!r}")
            ok = False
            data = repr(e)
            rc = 1
        dt = time.time() - t0
        if ok:
            ok, data = encode_section_data(data)
            if not ok:
                print(f"# section {name} returned unserializable data: "
                      f"{data['unserializable']}")
                rc = 1
        report["sections"][name] = {"ok": ok, "seconds": round(dt, 3),
                                    "data": data}
        print(f"# bench:{name} took {dt:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
