"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only SECTION] [--smoke]

Sections:
  compile   — §5.1 Fig 6: compression vs projection dependence-compute time
  taskgen   — task-generation throughput: compiled vs Fraction scanning
              backend on materialize / pred_count / roots (graphs verified
              identical)
  sync      — §2 Table 2: overhead counters per synchronization model
  executor  — §5.2: makespan comparison across models (+ threaded autodec)
  roofline  — §Roofline terms from the dry-run artifacts (if present)

``--smoke`` runs a fast subset of every section (small suites, no
subprocess projection timeouts) — a correctness-and-entry-point check that
finishes in well under a minute; full runs remain the default.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "compile", "taskgen", "sync", "executor",
                             "roofline"])
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset of each section (sub-minute total)")
    args = ap.parse_args(argv)

    from . import (bench_compile, bench_executor, bench_roofline,
                   bench_sync_overheads, bench_taskgen)

    sections = {
        "compile": bench_compile.run,
        "taskgen": bench_taskgen.run,
        "sync": bench_sync_overheads.run,
        "executor": bench_executor.run,
        "roofline": bench_roofline.run,
    }
    if args.only:
        sections = {args.only: sections[args.only]}
    rc = 0
    for name, fn in sections.items():
        print(f"\n===== bench:{name} =====", flush=True)
        t0 = time.time()
        kw = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kw["smoke"] = True
        try:
            fn(**kw)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"# section {name} failed: {e!r}")
            rc = 1
        print(f"# bench:{name} took {time.time()-t0:.1f}s", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
