"""Distributed counted-sync execution: per-rank task rate, message volume.

The distributed runtime (``docs/distributed.md``) claims the rank-owned
counter sweep keeps the single-host per-task cost while crossing the
process boundary only on true cross-rank dependence edges.  This benchmark
prices that claim on the jacobi2d flagship: for each (ranks, transport) it
runs the full message-decrement execution, verifies the merged frontiers
byte-identical to the single-host ``schedule_from_graph`` oracle, and
records

* end-to-end ``per_task_us`` (partition + sweep + merge, the number
  comparable to the ``executor``/``fused`` dispatch rows),
* cross-rank message volume — ``msgs`` (decrements carried), ``batches``
  (active messages sent), ``cross_frac`` (fraction of all edges that left
  their rank), and
* a ``per_rank`` breakdown ``{rank, n_local, started, msgs_out, msgs_in,
  per_task_us}`` exposing ownership imbalance.

Rows feed the ``distributed`` section of ``benchmarks/run.py``
(schema v7).  Smoke mode shrinks the graph and skips the process
transport; the full run covers the ≥1M-task flagship at 1/2/4 ranks on
both transports.
"""
from __future__ import annotations

import time

from repro.core.edt import (TiledTaskGraph, partition_graph, run_distributed,
                            schedule_from_graph)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS


def _rank_rows(run) -> list:
    rows = []
    for s in run.rank_stats:
        rows.append({
            "rank": s.rank, "n_local": s.n_local, "started": s.started,
            "supersteps": s.supersteps,
            "msgs_out": s.msgs_out, "msgs_in": s.msgs_in,
            "batches_out": s.batches_out,
            "per_task_us": round(s.seconds / max(1, s.started) * 1e6, 3),
        })
        assert s.started == s.n_local
    return rows


def run(emit=print, smoke: bool = False):
    params = {"T": 8, "N": 48} if smoke else {"T": 32, "N": 512}
    g = TiledTaskGraph(PROGRAMS["jacobi2d"](), {"S": Tiling((2, 2, 2))},
                       backend="compiled")
    t0 = time.time()
    ig = g.index_graph(params)
    sched = schedule_from_graph(ig)
    build_s = time.time() - t0
    emit(f"# distributed sweep: jacobi2d {params} -> {ig.n} tasks, "
         f"{ig.n_edges} edges (built in {build_s:.1f}s)")
    emit("ranks,transport,seconds,per_task_us,msgs,batches,cross_frac,"
         "verified")
    configs = [(1, "inline"), (2, "inline"), (4, "inline")]
    if not smoke:
        configs += [(2, "processes"), (4, "processes")]
    rows = []
    for ranks, transport in configs:
        cross = sum(int(sl.r_tgt.size) for sl in partition_graph(ig, ranks))
        t0 = time.time()
        r = run_distributed(ig, ranks=ranks, engine="numpy",
                            transport=transport, timeout=300.0)
        dt = time.time() - t0
        ok = (r.level_of.tobytes() == sched.level_of.tobytes()
              and r.depth == sched.depth)
        s = r.summary()
        assert s["msgs"] == cross      # every cross edge messaged once
        row = {
            "program": "jacobi2d", "tasks": ig.n, "ranks": ranks,
            "engine": "numpy", "transport": transport,
            "seconds": round(dt, 4),
            "per_task_us": round(dt / max(1, ig.n) * 1e6, 3),
            "msgs": s["msgs"], "batches": s["batches"],
            "cross_frac": round(cross / max(1, ig.n_edges), 4),
            "attempts": s["attempts"],
            "per_rank": _rank_rows(r),
            "verified": ok,
        }
        rows.append(row)
        emit(f"{ranks},{transport},{row['seconds']},{row['per_task_us']},"
             f"{row['msgs']},{row['batches']},{row['cross_frac']},{ok}")
        if not ok:
            raise AssertionError(
                f"distributed frontiers diverged at ranks={ranks} "
                f"transport={transport}")
    return {"rows": rows, "build_seconds": round(build_s, 3),
            "tasks": ig.n, "edges": ig.n_edges}
