"""Fused execution pricing: what does the EDT runtime *cost* once the
tasks do real work?

Four ways to run the same stencil solve (same grid, same taps, same
answer), priced per task and per grid point:

* ``device_replay``     — the PR-5 decrement-only sweep: counters + on-
                          device validation, tiles are phantoms.  The
                          fused sweep's budget: compute is only "free" if
                          adding it does not slow the sweep down.
* ``fused``             — :class:`~repro.core.edt.FusedExecutor` replay
                          with the on-device schedule validation on
                          (the default posture),
* ``fused_novalidate``  — the same sweep minus the three violation
                          counters; the fair comparison against the
                          decrement-only sweep (which prices one gather
                          per level where the fused validating sweep
                          prices three) and the ISSUE acceptance row,
* ``host_dispatch``     — :func:`~repro.core.edt.host_execute`, the
                          NumPy level-major twin: every level a host
                          round-trip (what "dispatch per wavefront"
                          costs without device residency),
* ``handwritten``       — :func:`~repro.kernels.stencils.handwritten_solve`,
                          the no-task-graph ``lax.fori_loop`` a
                          performance engineer writes given the whole
                          problem up front.  The honest upper bound: the
                          EDT sweep pays per *task*, this pays per time
                          step, so the gap (reported as
                          ``vs_handwritten``) is the price of generality.

Warm timings are best-of-3 after a cold (compiling) run.  Numerics are
asserted, not assumed: every fused/host row is checked against the
handwritten solve of the same initial grid (float32, rtol 1e-4 — ~1M-task
accumulation drift documented in docs/device_exec.md).  The full run's
flagship is the ≥1M-task jacobi2d acceptance case, where
``fused_novalidate`` per-task time must not exceed ``device_replay``.
Rows land in the CI JSON artifact via ``benchmarks/run.py --json``
(schema v6, section ``fused``).
"""
from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np

from repro.core.edt import (DeviceExecutor, ExecutionConfig, FusedExecutor,
                            TiledTaskGraph, host_execute, pack_origins,
                            synthesize_indexed)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS
from repro.kernels.stencils import SPECS, default_state, handwritten_solve

#: (program, tile sizes, params, shards, extras, flagship) — ``extras``
#: adds the host_dispatch row (a per-level host loop not worth re-pricing
#: at 1M tasks); ``flagship`` marks the acceptance case.
CASES = [
    ("jacobi2d", (2, 2, 2), {"T": 16, "N": 128}, 1, True, False),
    ("seidel1d", (2, 4), {"T": 64, "N": 256}, 1, True, False),
    ("jacobi2d", (2, 2, 2), {"T": 32, "N": 512}, 4, False, True),
]
SMOKE_CASES = [
    ("jacobi2d", (2, 2, 2), {"T": 8, "N": 64}, 2, True, False),
]

#: 1M-task float32 accumulation drift vs the reassociated handwritten
#: solve; small cases sit at ~1 ULP (tests/test_fused_exec.py pins both).
TOL = dict(rtol=1e-4, atol=1e-5)


def _best_of(fn, k: int = 3) -> float:
    best = float("inf")
    for _ in range(k):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(emit=print, smoke: bool = False):
    cases = SMOKE_CASES if smoke else CASES
    emit("program,path,tasks,points,seconds,per_task_us,per_point_ns,"
         "vs_handwritten,verified")
    rows = []
    need_pool = any(s > 1 for *_, s, _, _ in cases)
    pool = ProcessPoolExecutor(max_workers=2) if need_pool else None
    try:
        for name, tiles, params, shards, extras, flagship in cases:
            rows += _case(emit, name, tiles, params, shards, extras,
                          flagship, pool)
    finally:
        if pool is not None:
            pool.shutdown()

    bad = [r for r in rows if not r["verified"]]
    assert not bad, f"fused paths diverged from the handwritten solve: {bad}"
    acceptance = None
    for r in rows:
        if r["flagship"] and r["path"] == "fused_novalidate":
            base = next(x for x in rows
                        if x["flagship"] and x["path"] == "device_replay")
            acceptance = {
                "tasks": r["tasks"],
                "fused_novalidate_per_task_us": r["per_task_us"],
                "device_replay_per_task_us": base["per_task_us"],
                "le_decrement_only": r["per_task_us"] <= base["per_task_us"],
                "vs_handwritten": r["vs_handwritten"],
            }
            emit(f"# acceptance: fused {r['per_task_us']}us/task vs "
                 f"decrement-only {base['per_task_us']}us/task on "
                 f"{r['tasks']} tasks -> "
                 f"{'OK' if acceptance['le_decrement_only'] else 'FAIL'}")
            assert acceptance["le_decrement_only"], acceptance
    return {"rows": rows, "acceptance": acceptance}


def _case(emit, name, tiles, params, shards, extras, flagship, pool):
    spec = SPECS[name]
    g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                       backend="numpy")
    t0 = time.perf_counter()
    ig, sched = synthesize_indexed(g, params, config=ExecutionConfig(
        shards=shards if shards > 1 else None, pool=pool))
    emit(f"# {name} {params}: generation+leveling "
         f"{time.perf_counter()-t0:.2f}s ({ig.n} tasks, {ig.n_edges} "
         f"edges, depth {sched.depth})")
    points = params["T"] * params["N"] ** spec.space
    state = default_state(spec, params["N"], np.float32)

    handwritten_solve(spec, state, params["T"])              # compile
    hand_s = _best_of(lambda: handwritten_solve(spec, state, params["T"]))
    want = handwritten_solve(spec, state, params["T"])

    rows = []

    def row(path, seconds, final=None):
        ok = final is None or np.allclose(final, want, **TOL)
        r = {"program": name, "path": path, "tasks": ig.n, "points": points,
             "flagship": flagship, "seconds": round(seconds, 4),
             "per_task_us": round(1e6 * seconds / max(1, ig.n), 3),
             "per_point_ns": round(1e9 * seconds / max(1, points), 2),
             "vs_handwritten": round(seconds / hand_s, 2),
             "verified": bool(ok)}
        rows.append(r)
        emit(f"{name},{path},{ig.n},{points},{r['seconds']},"
             f"{r['per_task_us']},{r['per_point_ns']},"
             f"{r['vs_handwritten']},{r['verified']}")
        return r

    row("handwritten", hand_s)

    dev = DeviceExecutor(ig, schedule=sched)
    dev.run()                                                # compile
    row("device_replay", _best_of(dev.run))

    for path, validate in (("fused", True), ("fused_novalidate", False)):
        ex = FusedExecutor(ig, params, body=name, tile=tiles,
                           schedule=sched, state=state, validate=validate)
        run_ = ex.run()                                      # compile
        row(path, _best_of(ex.run), run_.final)

    if extras:
        fo = pack_origins(ig, tiles)
        t0 = time.perf_counter()
        final = host_execute(spec, tiles, params["T"], params["N"], fo,
                             sched.levels, state)
        row("host_dispatch", time.perf_counter() - t0, final)
    return rows
