"""Differential suite for the device-resident wavefront executor.

Every execution path that can drive a schedule must agree, bit for bit:

* ``DeviceExecutor`` discover mode (counters-only frontier derivation on
  the jax layer, XLA step and pallas-kernel step),
* ``DeviceExecutor`` replay mode (O(V+E) schedule sweep with on-device
  counted-sync validation),
* the host oracle: ``synthesize_indexed`` levels executed by
  ``simulate_indexed`` on the instrumented Sim.

Graphs come from the same seeded random-program generator as the backend
differential harness (``tests/test_backend_differential.py``) and are
built through the fraction / compiled / numpy backends and the sharded
engine — the device layer must be insensitive to how the index arrays were
produced.  The suite also covers the failure modes (cyclic graphs, sched-
ules that are not the counted execution), the pallas kernel's NumPy oracle
and its graceful absence, and the ≥1M-task jacobi2d acceptance run.
"""
from __future__ import annotations

import importlib
import random
import sys
from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest
from test_backend_differential import _build_program

from repro import compat
from repro.core.edt import (DeviceExecutor, ExecutionConfig, IndexedGraph,
                            TiledTaskGraph, levels_from_array,
                            simulate_indexed, synthesize_indexed)
from repro.core.edt.device import (decrement_reference, make_pallas_step,
                                   pack_graph, pack_schedule)
from repro.core.edt.wavefront import IndexedSchedule
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

BACKENDS = ("fraction", "compiled", "numpy")


@pytest.fixture(scope="module")
def pool():
    p = ProcessPoolExecutor(max_workers=2)
    p.submit(int, 0).result()
    yield p
    p.shutdown()


# ------------------------------------------------------------- comparator
def assert_device_matches_host(graph: TiledTaskGraph, params: dict,
                               shards=None, pool=None) -> None:
    """The differential property: device frontiers == host frontiers."""
    ig, sched = synthesize_indexed(
        graph, params, config=ExecutionConfig(shards=shards, pool=pool))
    runs = {
        "discover": DeviceExecutor(ig).run(),
        "replay": DeviceExecutor(ig, schedule=sched).run(),
    }
    sim = simulate_indexed(sched, workers=3)
    host_order = sim.exec_order
    for label, run in runs.items():
        # every task exactly once
        order = run.exec_order
        assert order.shape[0] == ig.n, label
        if ig.n:
            assert np.array_equal(np.sort(order), np.arange(ig.n)), label
        # topological: every edge crosses levels forward
        if ig.n_edges:
            assert (run.level_of[ig.edge_src]
                    < run.level_of[ig.edge_tgt]).all(), label
        # per-level frontiers byte-identical to the host schedule
        assert len(run.levels) == sched.depth, label
        for dev_lv, host_lv in zip(run.levels, sched.levels):
            assert dev_lv.dtype == host_lv.dtype, label
            assert np.array_equal(dev_lv, host_lv), label
        assert run.level_of.dtype == sched.level_of.dtype, label
        assert np.array_equal(run.level_of, sched.level_of), label
        # and the Sim replays exactly that order
        assert order.tolist() == host_order, label
        # Sim-mirror counters
        c = run.counters
        assert c.tasks_started == c.tasks_finished == ig.n, label
        assert c.depth == sched.depth, label
        assert c.max_in_flight == sched.max_width, label
        assert c.level_widths.tolist() == [lv.size for lv in sched.levels]


# ---------------------------------------------------------- differential
def test_differential_device_random_programs(pool):
    """Seeded sweep: random polyhedral programs, every build path."""
    rng = random.Random(20260731)
    for case in range(8):
        prog, tilings, params = _build_program(rng)
        for backend in BACKENDS:
            g = TiledTaskGraph(prog, tilings, backend=backend)
            assert_device_matches_host(g, params)
        g = TiledTaskGraph(prog, tilings, backend="numpy")
        assert_device_matches_host(g, params, shards=2, pool=pool)


def test_differential_device_named_programs(pool):
    """The paper-suite anchors (triangular, multi-dep, stencil, edgeless)."""
    cases = [
        ("trisolv", (2, 2), {"N": 21}),
        ("seidel1d", (3, 3), {"T": 9, "N": 21}),
        ("diamond", (1, 1), {"K": 9}),
        ("pipeline", (1, 1), {"M": 12, "S": 5}),
        ("embarrassing", (3,), {"N": 17}),
    ]
    for name, tiles, params in cases:
        g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                           backend="numpy")
        assert_device_matches_host(g, params)
        assert_device_matches_host(g, params, shards=2, pool=pool)


def test_device_packing_layout():
    """CSR + transpose-CSR columns agree with the flat edge arrays."""
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((2, 2))},
                       backend="numpy")
    ig = g.index_graph({"N": 15})
    dg = pack_graph(ig)
    assert dg.n == ig.n and dg.n_edges == ig.n_edges
    assert dg.indptr[-1] == dg.n_edges == dg.dec_ptr[-1]
    # successors of each task in CSR order == lex-sorted edge targets
    order = np.argsort(ig.edge_src, kind="stable")
    assert np.array_equal(dg.succ, ig.edge_tgt[order])
    # per-target group sizes are exactly the §4.3 counters
    assert np.array_equal(np.diff(dg.dec_ptr), ig.pred_n)
    assert np.array_equal(dg.pred_n, ig.pred_n)


# ------------------------------------------------------------- failures
def _two_task_cycle() -> IndexedGraph:
    blocks = [("S", np.asarray([[0], [1]], dtype=np.int64))]
    return IndexedGraph(
        stmt_blocks=blocks, n=2,
        edge_src=np.asarray([0, 1], dtype=np.int64),
        edge_tgt=np.asarray([1, 0], dtype=np.int64),
        pred_n=np.asarray([1, 1], dtype=np.int64))


def test_discover_detects_cycle():
    """Still a RuntimeError matching "cycle" (back-compat), but now a
    StallError carrying the structured report with the starved counters."""
    from repro.core.edt import StallError
    with pytest.raises(RuntimeError, match="cycle") as ei:
        DeviceExecutor(_two_task_cycle()).run()
    assert isinstance(ei.value, StallError)
    rep = ei.value.report
    assert rep.context == "device-discover"
    assert rep.started == 0 and set(rep.undrained) == {0, 1}


def test_replay_rejects_non_counted_schedule():
    """A schedule that is topologically valid but not the earliest-start
    counted execution (a task delayed past its frontier) must be flagged
    by the on-device validation — with the offending level and task ids
    named in the structured payload."""
    from repro.core.edt import ScheduleValidationError
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))},
                       backend="numpy")
    ig, sched = synthesize_indexed(g, {"K": 6})
    lv = sched.level_of.copy()
    moved = sched.levels[1][0]
    lv[moved] += 2                      # push one task two levels late
    bad = IndexedSchedule(levels=levels_from_array(lv), level_of=lv)
    with pytest.raises(RuntimeError, match="counted-sync") as ei:
        DeviceExecutor(ig, schedule=bad).run()
    e = ei.value
    assert isinstance(e, ScheduleValidationError)
    # the delayed task never decremented its successors, so the schedule
    # runs them not-ready one level after the delay
    assert e.kind == "not-ready"
    assert e.level == 2
    succ = ig.edge_tgt[ig.edge_src == int(moved)]
    assert set(e.task_ids) == set(int(s) for s in succ)
    assert e.counters["tasks"] == ig.n
    assert e.counters["device_not_ready"] == len(succ)


def test_replay_rejects_swapped_levels():
    from repro.core.edt import ScheduleValidationError
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))},
                       backend="numpy")
    ig, sched = synthesize_indexed(g, {"K": 6})
    lv = sched.level_of.copy()
    a, b = sched.levels[1][0], sched.levels[3][0]
    lv[a], lv[b] = lv[b], lv[a]         # order violation across levels
    bad = IndexedSchedule(levels=levels_from_array(lv), level_of=lv)
    with pytest.raises(RuntimeError, match="counted-sync") as ei:
        DeviceExecutor(ig, schedule=bad).run()
    e = ei.value
    # the late-level task scheduled early has an undrained counter there
    assert isinstance(e, ScheduleValidationError)
    assert e.kind == "not-ready"
    assert e.level == 1
    assert int(b) in e.task_ids


def test_pack_schedule_rejects_duplicate_ids():
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))},
                       backend="numpy")
    ig, sched = synthesize_indexed(g, {"K": 4})
    lv = sched.levels[0].copy()
    levels = [np.concatenate([lv, lv[:1]])] + sched.levels[1:]
    with pytest.raises(ValueError, match="exactly-once"):
        pack_schedule(ig, IndexedSchedule(levels=levels,
                                          level_of=sched.level_of))


# -------------------------------------------------------------- pallas
def _small_graph():
    g = TiledTaskGraph(PROGRAMS["seidel1d"](), {"S": Tiling((2, 2))},
                       backend="numpy")
    return synthesize_indexed(g, {"T": 8, "N": 18})


def test_pallas_step_matches_reference_and_xla():
    """One wavefront step: NumPy oracle == XLA step == pallas kernel
    (interpret mode on this CPU-only container), on every frontier of a
    real sweep."""
    import jax.numpy as jnp

    from repro.core.edt.device import _step_xla

    ig, sched = _small_graph()
    dg = pack_graph(ig)
    xla = _step_xla(jnp)
    pal = make_pallas_step(dg.n, dg.n_edges, interpret=True)
    indeg = dg.pred_n.copy()
    frontier = indeg == 0
    for _ in range(sched.depth):
        ref_indeg, ref_newly = decrement_reference(
            indeg, frontier, dg.dec_src, dg.dec_ptr)
        for name, step in (("xla", xla), ("pallas", pal)):
            got_indeg, got_newly = step(
                jnp.asarray(indeg), jnp.asarray(frontier),
                jnp.asarray(dg.dec_src), jnp.asarray(dg.dec_ptr))
            assert np.array_equal(np.asarray(got_indeg), ref_indeg), name
            assert np.array_equal(np.asarray(got_newly), ref_newly), name
        indeg, frontier = ref_indeg, ref_newly
    assert not frontier.any() and (indeg == 0).all()


def test_pallas_discover_run_identical():
    ig, sched = _small_graph()
    run = DeviceExecutor(ig, use_pallas=True).run()
    assert [lv.tolist() for lv in run.levels] == [
        lv.tolist() for lv in sched.levels]
    # the kernel prices the discover sweep only; silently measuring the
    # replay scatter path under a "pallas" label would mislead
    with pytest.raises(TypeError, match="discover sweep only"):
        DeviceExecutor(ig, schedule=sched, use_pallas=True)


def test_degrades_gracefully_without_pallas(monkeypatch):
    """When jax has no pallas, importing device.py and the default XLA
    path keep working; only ``use_pallas=True`` refuses, loudly."""
    import jax.experimental

    import repro.core.edt.device as device

    monkeypatch.delattr(jax.experimental, "pallas", raising=False)
    monkeypatch.setitem(sys.modules, "jax.experimental.pallas", None)
    assert compat.pallas() is None
    assert compat.has_pallas() is False
    importlib.reload(device)            # module import never touches pallas
    try:
        ig, sched = _small_graph()
        run = device.DeviceExecutor(ig).run()
        assert [lv.tolist() for lv in run.levels] == [
            lv.tolist() for lv in sched.levels]
        with pytest.raises(RuntimeError, match="no pallas"):
            device.DeviceExecutor(ig, use_pallas=True)
    finally:
        monkeypatch.undo()
        importlib.reload(device)        # restore a clean module for others
    assert compat.has_pallas() is True


# ------------------------------------------------------------- at scale
def test_million_task_jacobi2d_device_matches_host(pool):
    """The acceptance run: a ≥1M-task jacobi2d schedule end-to-end on the
    device executor, frontiers identical to what ``simulate_indexed``
    executes on the host Sim.

    In replay mode the run's level arrays ARE the validated input schedule
    (comparing them back to ``sched`` would be vacuous), so frontier
    identity rests on (1) the on-device violation counters — ``run()``
    raises unless the schedule is exactly the counted execution, a check
    the corrupt-schedule tests above prove has teeth — plus (2) an
    independent host-side check that every edge crosses frontiers forward,
    and (3) the Sim executing the same order."""
    g = TiledTaskGraph(PROGRAMS["jacobi2d"](), {"S": Tiling((2, 2, 2))},
                       backend="numpy")
    params = {"T": 32, "N": 512}
    ig, sched = synthesize_indexed(
        g, params, config=ExecutionConfig(shards=2, pool=pool))
    assert ig.n >= 1_000_000
    run = DeviceExecutor(ig, schedule=sched).run()   # (1) validates on device
    assert run.counters.tasks_finished == ig.n
    assert run.counters.depth == sched.depth
    assert run.counters.max_in_flight == sched.max_width
    # (2) independent of the device path and of synthesize_indexed's own
    # leveling loop: raw edge columns against the executed levels
    assert (run.level_of[ig.edge_src] < run.level_of[ig.edge_tgt]).all()
    order = run.exec_order
    assert np.array_equal(np.sort(order), np.arange(ig.n))
    # (3) the host Sim replays the identical order
    sim = simulate_indexed(sched, workers=8)
    assert order.shape[0] == len(sim.exec_order)
    assert np.array_equal(order, np.asarray(sim.exec_order))
