"""Polyhedral pipeline: schedule synthesis + multi-device execution.

The shard_map execution needs >1 device, so it runs in a subprocess with
XLA host-platform devices (tests themselves must see 1 device, per the
dry-run contract).
"""
import subprocess
import sys

import pytest

from repro.parallel.pipeline import build_schedule


def test_schedule_is_polyhedral_wavefront():
    s = build_schedule(n_microbatches=12, n_stages=5, tile_m=3)
    assert s.n_tiles == 4
    assert s.depth == 4 + 5 - 1
    # wavefront levels enumerate (mT, s) with mT + s == level
    for lvl, tasks in enumerate(s.levels):
        assert tasks, lvl
        for _, (mT, st) in tasks:
            assert mT + st == lvl


def test_schedule_rejects_ragged_tiling():
    with pytest.raises(AssertionError):
        build_schedule(n_microbatches=7, n_stages=2, tile_m=3)


def test_pipeline_matches_reference_and_trains():
    """Runs examples/pipeline_train.py (8 virtual devices) as the oracle."""
    proc = subprocess.run(
        [sys.executable, "examples/pipeline_train.py"],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "pipelined forward == sequential reference" in proc.stdout
    assert "pipeline_train OK" in proc.stdout
