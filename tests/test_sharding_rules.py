"""Sharding rules + ZeRO + elastic restore (subprocess: needs >1 device)."""
import subprocess
import sys
import textwrap



def _run(src: str, devices: int = 8, timeout: int = 600):
    prog = ("import os\n"
            f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(src))
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout,
                          env={**__import__('os').environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_param_specs_follow_rules():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import spec_for_param, zero_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # attention projections: col/row parallel
    assert spec_for_param("layers/attn/wq", (16, 64, 128), mesh) == P(None, None, "model")
    assert spec_for_param("layers/attn/wo", (16, 128, 64), mesh) == P(None, "model", None)
    # vocab-parallel embeddings
    assert spec_for_param("embed", (1024, 64), mesh) == P("model", None)
    # non-divisible dims drop the axis
    assert spec_for_param("layers/attn/wq", (16, 64, 129), mesh) == P(None, None, None)
    # experts: (data x model) when divisible, else model
    assert spec_for_param("moe_layers/moe/wg", (8, 8, 64, 32), mesh) == \
        P(None, ("data", "model"), None, None)
    assert spec_for_param("moe_layers/moe/wg", (8, 4, 64, 32), mesh) == \
        P(None, "model", None, None)
    # norms replicate
    assert spec_for_param("layers/ln1", (16, 64), mesh) == P()
    # ZeRO adds unused dp axes only
    assert zero_spec(P(None, "model"), (8, 64), mesh) == P("data", "model")
    assert zero_spec(P(("data", "model"), None), (8, 64), mesh) == \
        P(("data", "model"), None)
    print("RULES-OK")
    """)
    assert "RULES-OK" in out


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoint on an 8-device (2x4) mesh, restore onto 4 devices (2x2)."""
    out = _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_sync, restore, latest_step
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    w = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    w = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    save_sync(r"{tmp_path}", 5, {{"w": w}})

    # elastic restart: the new "cluster" is a 2x2 mesh over 4 of the devices
    small = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    target = jax.ShapeDtypeStruct(
        (8, 16), jnp.float32,
        sharding=NamedSharding(small, P("data", "model")))
    got = restore(r"{tmp_path}", 5, {{"w": target}})
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(8 * 16).reshape(8, 16))
    assert got["w"].sharding.mesh.shape["model"] == 2
    print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out


def test_compressed_allreduce_matches_mean():
    """int8 reduce-scatter/all-gather grad exchange ~= exact mean (shard_map)."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.compression import compressed_psum_grads
    mesh = jax.make_mesh((4,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 1024)) * 2.0

    def region(gs):
        return compressed_psum_grads({"g": gs[0]}, mesh, axis="data")["g"]

    out = jax.jit(shard_map(region, mesh=mesh, in_specs=P("data", None),
                            out_specs=P(None)))(g)
    want = g.mean(0)
    err = float(jnp.max(jnp.abs(out - want)))
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert err <= 2 * scale + 1e-6, (err, scale)
    print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out
