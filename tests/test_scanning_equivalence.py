"""Scanning-backend equivalence (regression gate).

The compiled backend (integer codegen) and the numpy backend (vectorized
batch codegen, ``iterate_array``/``count_vectorized``) must be *observably
identical* to the retained Fraction reference path: same iterated point
sets and orders, same counts, same enumerator-vs-loop strategy split, same
task/edge/root sets, same pred counts, same wavefront schedules, and same
Sim counter summaries and execution orders.  Any divergence here means the
integer normalization of a bound row — or its array translation — is wrong.

Also covered: the compiled-scan cache (identical canonical polyhedra across
graphs must share one generated function object).
"""
import numpy as np
import pytest

from repro.core.edt import TiledTaskGraph, run_model, synthesize, validate_order
from repro.core.poly import LoopNest, Tiling, clear_scan_cache, scan_cache_info
from repro.core.programs import PROGRAMS

# Small-but-nontrivial shapes: odd params so tiles are ragged at the borders.
CASES = {
    "stencil1d": ((2, 3), {"T": 5, "N": 9}),
    "seidel1d": ((2, 2), {"T": 4, "N": 7}),
    "jacobi2d": ((2, 2, 2), {"T": 3, "N": 5}),
    "heat3d": ((2, 2, 2, 2), {"T": 3, "N": 4}),
    "matmul": ((2, 2, 2), {"N": 5}),
    "trisolv": ((3, 2), {"N": 9}),
    "cholesky_like": ((2, 2, 2), {"N": 5}),
    "lu_like": ((2, 2, 2), {"N": 5}),
    "fanout2": ((2, 3), {"L": 4, "W": 7}),
    "fanout8": ((2, 3), {"L": 3, "W": 9}),
    "diamond": ((2, 2), {"K": 7}),
    "pipeline": ((2, 1), {"M": 5, "S": 3}),
    "embarrassing": ((4,), {"N": 13}),
    "synthetic5d": ((2,) * 5, {"N": 4}),
    "synthetic6d": ((2,) * 6, {"N": 4}),
}

assert set(CASES) == set(PROGRAMS), "every program must be covered"


def _graphs(name, backends=("compiled", "fraction")):
    tiles, params = CASES[name]
    tilings = {"S": Tiling(tiles)}
    gs = [TiledTaskGraph(PROGRAMS[name](), tilings, backend=b)
          for b in backends]
    return (*gs, params)


@pytest.mark.parametrize("name", sorted(CASES))
def test_backend_equivalence(name):
    gc, gf, params = _graphs(name)

    # tile-domain scanning: same points in the same (lexicographic) order
    for st in gc.program.statements:
        pc = list(gc.tile_nests[st].iterate(params))
        pf = list(gf.tile_nests[st].iterate(params))
        assert pc == pf
        assert gc.tile_nests[st].count(params) == len(pc)
        assert gf.tile_nests[st].count(params) == len(pf)

    # §4.3 strategy split (enumerator vs counting loop) must match
    assert gc.pred_count_strategies() == gf.pred_count_strategies()

    # materialized graph: identical task lists, edge lists, pred counts
    mc, mf = gc.materialize(params), gf.materialize(params)
    assert mc.tasks == mf.tasks
    assert mc.succ == mf.succ
    assert mc.pred_n == mf.pred_n

    # generated loops: per-task get/put loops and counter agree
    for t in mc.tasks:
        assert gc.pred_count(t, params) == gf.pred_count(t, params)
        assert list(gc.predecessors(t, params)) == list(gf.predecessors(t, params))

    # root sets (including the self-pair special case) agree
    assert list(gc.roots(params)) == list(gf.roots(params))


@pytest.mark.parametrize("name", sorted(CASES))
def test_numpy_backend_equivalence(name):
    """The vectorized backend's batch products equal the scalar graph,
    byte for byte: point arrays, counts, graphs, roots, counters, levels."""
    gc, gn, params = _graphs(name, backends=("compiled", "numpy"))

    # array enumeration: same points, same lexicographic order, same counts
    for st in gc.program.statements:
        pts = list(gc.tile_nests[st].iterate(params))
        arr = gn.tile_nests[st].iterate_array(params)
        assert arr.dtype == np.int64 and arr.shape == (len(pts), len(pts[0]))
        assert [tuple(r) for r in arr.tolist()] == pts
        assert gn.tile_nests[st].count_vectorized(params) == len(pts)
        # scalar APIs on the numpy backend share the compiled path
        assert list(gn.tile_nests[st].iterate(params)) == pts

    # materialized graph (dict view) is identical
    mc, mn = gc.materialize(params), gn.materialize(params)
    assert mc.tasks == mn.tasks
    assert mc.succ == mn.succ
    assert mc.pred_n == mn.pred_n

    # index-graph (native array view) carries the same graph
    ig = gn.index_graph(params)
    assert ig.n == len(mc.tasks) and ig.n_edges == mc.n_edges
    assert ig.tasks == mc.tasks
    assert ig.pred_n.tolist() == [mc.pred_n[t] for t in mc.tasks]

    # batched pred counts equal the §4.3 per-task counter
    for st, arr in gn.tasks_arrays(params).items():
        blk = gn.pred_count_block(st, arr, params)
        ref = [gc.pred_count((st, tuple(r)), params) for r in arr.tolist()]
        assert blk.tolist() == ref

    # root sets and wavefront schedules agree
    assert list(gc.roots(params)) == list(gn.roots(params))
    wc, wn = synthesize(gc, params), synthesize(gn, params)
    assert wc.levels == wn.levels
    assert wc.level_of == wn.level_of


@pytest.mark.parametrize("name", ["jacobi2d", "trisolv", "diamond"])
@pytest.mark.parametrize("backend", ["fraction", "numpy"])
def test_backend_identical_execution(name, backend):
    """Table-2 counters and exec order are bit-identical across backends."""
    gc, go, params = _graphs(name, backends=("compiled", backend))
    for model in ("prescribed", "counted", "autodec"):
        rc = run_model(model, gc, params, workers=3)
        ro = run_model(model, go, params, workers=3)
        assert rc.order == ro.order, model
        assert rc.counters.summary() == ro.counters.summary(), model
        validate_order(gc, params, rc)


def test_counting_function_backend_split():
    """All strategies of §4.3 give equal values under every backend."""
    from repro.core.poly import Polyhedron, make_counting_function

    tri = Polyhedron.from_ineqs(("i", "j"), ("N",), [
        (1, 0, 0, 0), (-1, 1, 0, 0), (0, -1, 1, -1)])
    for count_dims, fixed_dims, coords_list in [
            ([0], [1], [((j,),) for j in range(6)]),
            ([0, 1], [], [((),)]),
    ]:
        fc = make_counting_function(tri, count_dims, fixed_dims)
        ff = make_counting_function(tri, count_dims, fixed_dims,
                                    backend="fraction")
        fn = make_counting_function(tri, count_dims, fixed_dims,
                                    backend="numpy")
        assert fc.strategy == ff.strategy == fn.strategy
        for (coords,) in coords_list:
            assert fc(coords, (6,)) == ff(coords, (6,)) == fn(coords, (6,))
            assert list(fc.points(coords, (6,))) == list(ff.points(coords, (6,)))
        if coords_list[0][0]:
            block = np.asarray([c for (c,) in coords_list], dtype=np.int64)
            ref = [fc(tuple(r), (6,)) for r in block.tolist()]
            assert fn.count_block(block, (6,)).tolist() == ref
            # empty blocks are fine, including non-2-D inputs
            assert fn.count_block(np.zeros((0, 1), np.int64), (6,)).shape == (0,)
            assert fn.count_block([], (6,)).shape == (0,)


def test_scan_cache_shares_compiled_nests():
    """Two graphs over the same program share one compiled scan function
    per canonical polyhedron (ROADMAP cache item)."""
    clear_scan_cache()
    tiles, params = CASES["jacobi2d"]
    tilings = {"S": Tiling(tiles)}
    g1 = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings)
    g2 = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings)
    m1 = g1.materialize(params)
    g1.pred_count(m1.tasks[0], params)  # force the counter codegen too
    before = scan_cache_info()
    m2 = g2.materialize(params)
    g2.pred_count(m2.tasks[0], params)
    after = scan_cache_info()
    # the second graph compiled nothing new: only cache hits were added
    assert after["size"] == before["size"]
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]
    # the generated function objects are literally shared
    for st in g1.program.statements:
        assert g1.tile_nests[st]._scan_fn is g2.tile_nests[st]._scan_fn
    for t1, t2 in zip(g1.tiled_deps, g2.tiled_deps):
        assert t1.succ_fn.nest._scan_fn is t2.succ_fn.nest._scan_fn
        for fn in (t1.pred_fn, t2.pred_fn):
            fn.nest.count([0] * fn.nest.nparam)  # force counter codegen
        assert t1.pred_fn.nest._count_fn is not None
        assert t1.pred_fn.nest._count_fn is t2.pred_fn.nest._count_fn
    # the numpy flavor shares through the same key
    n1 = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings, backend="numpy")
    n2 = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings, backend="numpy")
    n1.materialize(params)
    n2.materialize(params)
    for t1, t2 in zip(n1.tiled_deps, n2.tiled_deps):
        assert t1.joint_nest._scan_np_fn is t2.joint_nest._scan_np_fn


def test_unbounded_dim_raises_in_both_backends():
    from repro.core.poly import Polyhedron

    half = Polyhedron.from_ineqs(("x",), (), [(1, 0)])  # x >= 0, unbounded
    for backend in ("compiled", "fraction"):
        nest = LoopNest(half, backend=backend)
        with pytest.raises(ValueError):
            list(nest.iterate(()))
        with pytest.raises(ValueError):
            nest.count(())
    nest = LoopNest(half, backend="numpy")
    with pytest.raises(ValueError):
        nest.iterate_array(())
    with pytest.raises(ValueError):
        nest.count_vectorized(())


def test_unbounded_inner_dim_with_empty_outer_range():
    """An empty outer loop must hide an unbounded inner dim identically.

    {0 <= i <= N, j >= i}: dim j is unbounded, but for N < 0 the i-range is
    empty, so iterate() yields nothing (and never reaches the raise) in all
    backends; for N >= 0 all raise on first consumption."""
    from repro.core.poly import Polyhedron

    P = Polyhedron.from_ineqs(("i", "j"), ("N",), [
        (1, 0, 0, 0), (-1, 0, 1, 0), (-1, 1, 0, 0)])
    for backend in ("compiled", "fraction"):
        nest = LoopNest(P, backend=backend)
        assert list(nest.iterate((-1,))) == [], backend
        with pytest.raises(ValueError):
            list(nest.iterate((2,)))
    nest = LoopNest(P, backend="numpy")
    assert nest.iterate_array((-1,)).shape == (0, 2)
    assert nest.count_vectorized((-1,)) == 0
    with pytest.raises(ValueError):
        nest.iterate_array((2,))
