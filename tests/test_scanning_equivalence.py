"""Compiled-vs-Fraction scanning backend equivalence (regression gate).

The compiled backend (integer codegen, ``scanning.py``) must be *observably
identical* to the retained Fraction reference path: same iterated point
sets and orders, same counts, same enumerator-vs-loop strategy split, same
task/edge/root sets, same pred counts, and same Sim counter summaries and
execution orders.  Any divergence here means the integer normalization of a
bound row is wrong.
"""
import pytest

from repro.core.edt import TiledTaskGraph, run_model, validate_order
from repro.core.poly import LoopNest, Tiling
from repro.core.programs import PROGRAMS

# Small-but-nontrivial shapes: odd params so tiles are ragged at the borders.
CASES = {
    "stencil1d": ((2, 3), {"T": 5, "N": 9}),
    "seidel1d": ((2, 2), {"T": 4, "N": 7}),
    "jacobi2d": ((2, 2, 2), {"T": 3, "N": 5}),
    "heat3d": ((2, 2, 2, 2), {"T": 3, "N": 4}),
    "matmul": ((2, 2, 2), {"N": 5}),
    "trisolv": ((3, 2), {"N": 9}),
    "lu_like": ((2, 2, 2), {"N": 5}),
    "diamond": ((2, 2), {"K": 7}),
    "pipeline": ((2, 1), {"M": 5, "S": 3}),
    "embarrassing": ((4,), {"N": 13}),
    "synthetic5d": ((2,) * 5, {"N": 4}),
    "synthetic6d": ((2,) * 6, {"N": 4}),
}

assert set(CASES) == set(PROGRAMS), "every program must be covered"


def _graphs(name):
    tiles, params = CASES[name]
    tilings = {"S": Tiling(tiles)}
    gc = TiledTaskGraph(PROGRAMS[name](), tilings)
    gf = TiledTaskGraph(PROGRAMS[name](), tilings, backend="fraction")
    return gc, gf, params


@pytest.mark.parametrize("name", sorted(CASES))
def test_backend_equivalence(name):
    gc, gf, params = _graphs(name)

    # tile-domain scanning: same points in the same (lexicographic) order
    for st in gc.program.statements:
        pc = list(gc.tile_nests[st].iterate(params))
        pf = list(gf.tile_nests[st].iterate(params))
        assert pc == pf
        assert gc.tile_nests[st].count(params) == len(pc)
        assert gf.tile_nests[st].count(params) == len(pf)

    # §4.3 strategy split (enumerator vs counting loop) must match
    assert gc.pred_count_strategies() == gf.pred_count_strategies()

    # materialized graph: identical task lists, edge lists, pred counts
    mc, mf = gc.materialize(params), gf.materialize(params)
    assert mc.tasks == mf.tasks
    assert mc.succ == mf.succ
    assert mc.pred_n == mf.pred_n

    # generated loops: per-task get/put loops and counter agree
    for t in mc.tasks:
        assert gc.pred_count(t, params) == gf.pred_count(t, params)
        assert list(gc.predecessors(t, params)) == list(gf.predecessors(t, params))

    # root sets (including the self-pair special case) agree
    assert list(gc.roots(params)) == list(gf.roots(params))


@pytest.mark.parametrize("name", ["jacobi2d", "trisolv", "diamond"])
def test_backend_identical_execution(name):
    """Table-2 counters and exec order are bit-identical across backends."""
    gc, gf, params = _graphs(name)
    for model in ("prescribed", "counted", "autodec"):
        rc = run_model(model, gc, params, workers=3)
        rf = run_model(model, gf, params, workers=3)
        assert rc.order == rf.order, model
        assert rc.counters.summary() == rf.counters.summary(), model
        validate_order(gc, params, rc)


def test_counting_function_backend_split():
    """Both strategies of §4.3 give equal values under both backends."""
    from repro.core.poly import Polyhedron, make_counting_function

    tri = Polyhedron.from_ineqs(("i", "j"), ("N",), [
        (1, 0, 0, 0), (-1, 1, 0, 0), (0, -1, 1, -1)])
    for count_dims, fixed_dims, coords_list in [
            ([0], [1], [((j,),) for j in range(6)]),
            ([0, 1], [], [((),)]),
    ]:
        fc = make_counting_function(tri, count_dims, fixed_dims)
        ff = make_counting_function(tri, count_dims, fixed_dims,
                                    backend="fraction")
        assert fc.strategy == ff.strategy
        for (coords,) in coords_list:
            assert fc(coords, (6,)) == ff(coords, (6,))
            assert list(fc.points(coords, (6,))) == list(ff.points(coords, (6,)))


def test_unbounded_dim_raises_in_both_backends():
    from repro.core.poly import Polyhedron

    half = Polyhedron.from_ineqs(("x",), (), [(1, 0)])  # x >= 0, unbounded
    for backend in ("compiled", "fraction"):
        nest = LoopNest(half, backend=backend)
        with pytest.raises(ValueError):
            list(nest.iterate(()))
        with pytest.raises(ValueError):
            nest.count(())


def test_unbounded_inner_dim_with_empty_outer_range():
    """An empty outer loop must hide an unbounded inner dim identically.

    {0 <= i <= N, j >= i}: dim j is unbounded, but for N < 0 the i-range is
    empty, so iterate() yields nothing (and never reaches the raise) in both
    backends; for N >= 0 both raise on first consumption."""
    from repro.core.poly import Polyhedron

    P = Polyhedron.from_ineqs(("i", "j"), ("N",), [
        (1, 0, 0, 0), (-1, 0, 1, 0), (-1, 1, 0, 0)])
    for backend in ("compiled", "fraction"):
        nest = LoopNest(P, backend=backend)
        assert list(nest.iterate((-1,))) == [], backend
        with pytest.raises(ValueError):
            list(nest.iterate((2,)))
