"""Substrate tests: data determinism, checkpoint atomicity + async chain,
fault-tolerant driver (restart, straggler backup), optimizer, compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore,
                              save_sync)
from repro.data import DataConfig, PrefetchPipeline, SyntheticLM
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.parallel.compression import quantize_dequantize_grads
from repro.runtime import DriverConfig, TrainDriver
from repro.runtime.driver import run_with_backup


def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=8)
    a = SyntheticLM(cfg, host_id=0, n_hosts=2)
    b = SyntheticLM(cfg, host_id=1, n_hosts=2)
    x1 = a.batch_at(7)
    x2 = a.batch_at(7)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    assert x1["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(a.batch_at(7)["tokens"]),
                              np.asarray(b.batch_at(7)["tokens"]))


def test_prefetch_pipeline_order_and_refill():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    pipe = PrefetchPipeline(SyntheticLM(cfg), depth=2)
    steps = []
    for _ in range(6):
        s, batch = pipe.get()
        steps.append(s)
        assert batch["tokens"].shape == (2, 8)
    pipe.close()
    assert steps == list(range(6))


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    save_sync(tmp_path, 3, tree)
    # a partial (manifest-less) later step must be ignored
    bad = tmp_path / "step_00000007"
    bad.mkdir()
    (bad / "arr_0.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 3
    out = restore(tmp_path, 3, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_async_checkpointer_chain(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (0, 1, 2, 3):
        ck.submit(s, {"x": jnp.full((4,), s)})
    assert ck.wait(60)
    ck.close()
    assert latest_step(tmp_path) == 3
    out = restore(tmp_path, 3, {"x": jnp.zeros((4,))})
    np.testing.assert_array_equal(out["x"], jnp.full((4,), 3))
    # GC kept only the last 2
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2


def _toy_trainer(tmp_path, fault_hook=None, steps=12):
    opt_cfg = AdamWConfig(lr=1e-2, warmup=2, total_steps=steps)

    def init_fn():
        params = {"w": jnp.ones((4, 4))}
        return params, init_state(opt_cfg, params)

    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            x = batch["tokens"].astype(jnp.float32)
            return jnp.mean((x[:, :4] @ p["w"]) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt_state = apply_updates(opt_cfg, params, g, opt_state)
        return params, opt_state, loss

    cfg = DriverConfig(total_steps=steps, ckpt_every=4,
                       ckpt_dir=str(tmp_path), max_restarts=3)
    data = DataConfig(vocab=17, seq_len=8, global_batch=2)
    return TrainDriver(cfg, data, train_step, init_fn,
                       fault_hook=fault_hook)


def test_driver_runs_and_checkpoints(tmp_path):
    drv = _toy_trainer(tmp_path)
    hist = drv.run()
    assert [h.step for h in hist] == list(range(12))
    assert latest_step(tmp_path) == 11


def test_driver_recovers_from_injected_fault(tmp_path):
    state = {"fired": False}

    def fault(step):
        if step == 9 and not state["fired"]:
            state["fired"] = True
            raise RuntimeError("injected node failure")

    drv = _toy_trainer(tmp_path, fault_hook=fault)
    hist = drv.run()
    assert drv.restarts == 1
    # the fault hits before step 9 runs; restart restores the step-7
    # checkpoint, so step 8 is replayed (appears twice) and 9..11 complete
    steps = [h.step for h in hist]
    assert steps.count(8) == 2 and steps.count(9) == 1 and steps[-1] == 11
    # deterministic data stream => the replayed step produces the same loss
    losses8 = [h.loss for h in hist if h.step == 8]
    assert abs(losses8[0] - losses8[1]) < 1e-6


def test_straggler_backup_first_completion_wins():
    def slow():
        time.sleep(2.0)
        return "slow"

    def fast():
        return "fast"

    val, by = run_with_backup(slow, deadline_s=0.1, backup=fast)
    assert val == "fast" and by == "backup"
    val, by = run_with_backup(fast, deadline_s=5.0)
    assert val == "fast" and by == "primary"


@pytest.mark.parametrize("bits", [32, 8])
def test_adamw_reduces_loss(bits):
    opt_cfg = AdamWConfig(lr=5e-2, warmup=1, total_steps=50, state_bits=bits)
    w = {"w": jnp.ones((256, 256)) * 2.0}   # big enough to quantize
    st = init_state(opt_cfg, w)
    tgt = jnp.zeros((256, 256))

    def loss(p):
        return jnp.mean((p["w"] - tgt) ** 2)

    l0 = float(loss(w))
    for _ in range(20):
        lval, g = jax.value_and_grad(loss)(w)
        w, st = apply_updates(opt_cfg, w, g, st)
    assert float(loss(w)) < l0 * 0.5
    if bits == 8:
        mv = st["mv"]["w"]
        assert mv.m.dtype == jnp.int8 and mv.m_scale is not None
        assert mv.m.shape == (256, 256)    # shape-preserving quantization


def test_grad_compression_roundtrip_precision():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0}
    gq = quantize_dequantize_grads(g)
    err = jnp.max(jnp.abs(gq["a"] - g["a"]))
    scale = jnp.max(jnp.abs(g["a"]))
    assert float(err) <= float(scale) / 127 + 1e-6


def test_microbatched_train_step_matches_full_batch():
    """Grad accumulation (launch.steps) == full-batch step, toy scale."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.launch.steps import init_all, make_train_step

    cfg = get_config("smollm-360m").smoke_config().replace(remat=False)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup=1)
    params, opt = init_all(model, opt_cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = {"tokens": (jnp.arange(8 * 16).reshape(8, 16) % 11).astype(jnp.int32),
             "labels": (jnp.arange(8 * 16).reshape(8, 16) % 7).astype(jnp.int32)}
    p1, _, l1 = jax.jit(make_train_step(model, opt_cfg))(params, opt, batch)
    p4, _, l4 = jax.jit(make_train_step(model, opt_cfg, microbatches=4))(
        params, opt, batch)
    assert abs(float(l1) - float(l4)) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
