"""Table-2 overhead asymptotics, asserted on growing graph sizes.

The paper's §2 comparison is qualitative ("scales with the number of
tasks/edges", "O(1) start-up"); these tests pin the measured counters of
each synchronization model to those shapes on the diamond DAG (the paper's
worst case for prescribed synchronization, Fig 1) at increasing sizes:

* ``prescribed`` start-up is exactly tasks + edges (the master declares
  everything); ``counted`` start-up is exactly tasks — both grow linearly.
* ``autodec`` start-up stays O(1) and its master does only the
  statically-computed root set (preschedule).
* ``tags1`` spatial peak tracks the edge count (one-use tags); ``tags2``
  tags are disposable only at completion, so its garbage gauge holds
  every producer's tag at the end while every other model drains to zero.
* ``autodec`` live counters peak at the frontier, not the graph.
"""
from __future__ import annotations

import json

from repro.core.edt import (MODELS, PolyhedralProgram, TiledTaskGraph, atlas,
                            validate_order)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

SIZES = (4, 8, 12)


def _measurements():
    out = []
    for k in SIZES:
        g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
        params = {"K": k}
        m = g.materialize(params)
        runs = {}
        for name, fn in MODELS.items():
            r = fn(g, params, workers=4)
            validate_order(g, params, r)
            runs[name] = r.counters
        out.append((k, len(m.tasks), m.n_edges,
                    len(list(g.roots(params))), runs))
    return out


MEASURED = None


def _runs():
    global MEASURED
    if MEASURED is None:
        MEASURED = _measurements()
    return MEASURED


def test_prescribed_and_counted_startup_grow_with_tasks():
    for k, n, e, _, runs in _runs():
        assert runs["prescribed"].startup_ops == n + e
        assert runs["counted"].startup_ops == n
    startups = [runs["prescribed"].startup_ops for *_, runs in _runs()]
    assert startups == sorted(startups) and startups[0] < startups[-1]


def test_autodec_startup_is_o1_plus_roots():
    for k, n, e, roots, runs in _runs():
        assert runs["autodec"].startup_ops == 1      # O(1): gate never closes
        assert runs["autodec"].master_ops == roots   # preschedule = root set
        assert runs["autodec_nosrc"].startup_ops == 1
        assert runs["autodec_nosrc"].master_ops == n  # w/o src: all tasks
    # the root set, not the graph, sizes the master's work: on the
    # embarrassing program every task is a root and the master does N ops
    g = TiledTaskGraph(PROGRAMS["embarrassing"](), {"S": Tiling((1,))})
    r = MODELS["autodec"](g, {"N": 23}, workers=4)
    assert r.counters.master_ops == 23
    assert r.counters.startup_ops == 1


def test_tags1_spatial_peak_tracks_edges():
    peaks = []
    for k, n, e, _, runs in _runs():
        peak = runs["tags1"].spatial.peak
        # every edge becomes one one-use tag (+1 transient pending get)
        assert e - 1 <= peak <= e + 1
        assert runs["tags1"].spatial.total == 2 * e  # tag + pending get
        peaks.append(peak)
    assert peaks == sorted(peaks) and peaks[0] < peaks[-1]


def test_counted_spatial_is_tasks_autodec_is_frontier():
    for k, n, e, _, runs in _runs():
        assert runs["counted"].spatial.peak == n
        # autodec keeps only live frontier counters — far below n, and its
        # lifetime total still covers every task exactly once
        assert runs["autodec"].spatial.peak < n // 2
        assert runs["autodec"].spatial.total == n


def test_garbage_drains_to_zero_except_tags2():
    for k, n, e, _, runs in _runs():
        for name in ("prescribed", "tags1", "counted", "autodec",
                     "autodec_nosrc"):
            assert runs[name].garbage.cur == 0, name
            assert runs[name].inflight_deps.cur == 0, name
            assert runs[name].inflight_tasks.cur == 0, name
        # tags2 tags are only disposable at graph completion: every task
        # that produced a tag still holds it as garbage at the end
        assert runs["tags2"].garbage.cur == n - 1


def test_every_model_covered_and_validated():
    """``validate_order`` ran for every model at every size inside
    ``_measurements`` (exactly-once + dependence-respecting order); this
    pins that the registry was fully covered."""
    for *_, runs in _runs():
        assert set(runs) == set(MODELS)


def test_tags_models_survive_multigraph_edges():
    """Two dependences relating the same task pair (a multigraph) must not
    break any model — regression for the tags1 tag table, which assumed
    one tag per (src, dst) key and crashed deleting the key twice."""
    from repro.core.poly import Polyhedron
    from repro.core.programs import dep

    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(("i",), ("N",), [(1, 0, 0), (-1, 1, -1)])
    P.add_statement("S", D)
    step = dep(D, D, eqs=[(1, -1, 0, 1)])        # i_t = i_s + 1, twice
    P.add_dependence("S", "S", step, "a")
    P.add_dependence("S", "S", step, "b")
    g = TiledTaskGraph(P, {"S": Tiling((1,))})
    params = {"N": 6}
    m = g.materialize(params)
    assert m.n_edges == 2 * 5                    # both edges materialized
    for name, fn in MODELS.items():
        r = fn(g, params, workers=2)
        validate_order(g, params, r)
        if name == "tags1":
            # one one-use tag + one pending get per dependence INSTANCE
            assert r.counters.spatial.total == 2 * m.n_edges


# ------------------------------------------------------------------- atlas
#
# The Table-2 atlas (core/edt/atlas.py): the smoke sweep must reproduce the
# paper's asymptotic classes on every (model, program, counter) its ladders
# can measure — this is the CI gate behind the sync-atlas artifact.

_ATLAS = None


def _atlas():
    global _ATLAS
    if _ATLAS is None:
        _ATLAS = atlas.sweep(smoke=True)
    return _ATLAS


def test_atlas_smoke_matches_table2():
    res = _atlas()
    assert res["fit_failures"] == [], res["fit_failures"]
    rows = res["rows"]
    # acceptance floor: >= 5 sync models x >= 3 program classes
    assert len({r["model"] for r in rows}) >= 5
    assert len({r["family"] for r in rows}) >= 3
    assert len({r["program"] for r in rows}) >= 3
    for f in res["fits"]:
        assert f["relation"] in ("match", "below")
        assert set(f["expected"]) <= set(atlas.CLASSES)


def test_atlas_rows_json_round_trip_with_string_keys():
    """The whole sweep payload is structured JSON — the (model, K)
    tuple-key bug class (shipped as ``repr`` from schema v2 to v7) can
    never reappear."""
    res = _atlas()
    assert json.loads(json.dumps(res))
    for r in res["rows"]:
        assert all(isinstance(k, str) for k in r)


def test_atlas_fit_class_picks_the_generating_class():
    refs = {"1": [1.0] * 3, "r": [4.0, 8.0, 16.0], "n": [16.0, 64.0, 256.0],
            "e": [40.0, 320.0, 2560.0], "n2": [256.0, 4096.0, 65536.0]}
    for cls in ("r", "n", "e", "n2"):
        assert atlas.fit_class([2 * v for v in refs[cls]], refs)["cls"] == cls
    # an exact match fits with scale 1 and no residual
    fit = atlas.fit_class([16, 64, 256], refs)
    assert fit["cls"] == "n" and fit["scale"] == 1.0 and fit["resid"] == 0.0
    # an all-zero counter is class 1, not a log-domain error
    assert atlas.fit_class([0, 0, 0], refs)["cls"] == "1"


def test_atlas_indistinguishability_is_data_driven():
    insts = atlas.build_instances(atlas.WORKLOADS[0], smoke=True)  # diamond
    refs = atlas.reference_curves(insts)
    assert atlas._indistinct(refs, "n", "e")       # e ~ 2n on the grid
    assert not atlas._indistinct(refs, "r", "n")   # frontier vs area


def test_atlas_growth_factors_honest_about_zero():
    """0 -> 0 is flat (1.0) and 0 -> b is born-at-scale (None); neither is
    masked by a max(1, ...) floor, and the task factor is measured."""
    base = {"program": "p", "model": "m", "grain": 1.0,
            "inflight_tasks_peak": 2, "garbage_peak": 1}
    rows = [
        dict(base, size="a", n_tasks=10, n_edges=18, width=4,
             startup_ops=0, spatial_peak=5, inflight_deps_peak=0),
        dict(base, size="b", n_tasks=40, n_edges=76, width=8,
             startup_ops=0, spatial_peak=20, inflight_deps_peak=3,
             inflight_tasks_peak=8, garbage_peak=0),
    ]
    (g,) = atlas.growth_rows(rows)
    assert g["task_factor"] == 4.0          # measured, not a K^2 closed form
    assert g["startup_ops"] == 1.0          # 0 -> 0 stays flat
    assert g["inflight_deps_peak"] is None  # born at scale, not x3
    assert g["spatial_peak"] == 4.0
    assert g["garbage_peak"] == 0.0         # a drop is a drop, not x1


def test_atlas_grain_axis_prices_startup_not_counters():
    """Lifetime object counts are grain-invariant; only makespan moves."""
    insts = atlas.build_instances(atlas.WORKLOADS[0], smoke=True)
    fine = atlas.measure(insts[0], "counted", grain=0.2)
    coarse = atlas.measure(insts[0], "counted", grain=5.0)
    for c in atlas.ATLAS_COUNTERS:
        assert fine[c] == coarse[c], c
    assert coarse["makespan"] > fine["makespan"]


def test_atlas_expected_covers_every_model_and_counter():
    assert set(atlas.EXPECTED) == set(MODELS)
    for spec in atlas.EXPECTED.values():
        assert set(spec) == set(atlas.ATLAS_COUNTERS)
        for classes in spec.values():
            assert classes and set(classes) <= set(atlas.CLASSES)
    # the table's headline start-up rows
    assert atlas.EXPECTED["prescribed"]["startup_ops"] == ("e",)
    assert atlas.EXPECTED["counted"]["startup_ops"] == ("n",)
    for m in ("tags1", "tags2", "autodec", "autodec_nosrc"):
        assert atlas.EXPECTED[m]["startup_ops"] == ("1",)


def test_atlas_crossover_smoke_verified():
    res = atlas.crossover(smoke=True)
    paths = {r["path"] for r in res["rows"]}
    assert paths == {"host_sim", "device_replay", "distributed_inline_2"}
    for r in res["rows"]:
        if r["path"] == "host_sim" or "skipped" not in r:
            assert r["verified"], r
            assert r["per_task_us"] > 0
    assert set(res["points"]) == {"device_replay", "distributed_inline_2"}
