"""Table-2 overhead asymptotics, asserted on growing graph sizes.

The paper's §2 comparison is qualitative ("scales with the number of
tasks/edges", "O(1) start-up"); these tests pin the measured counters of
each synchronization model to those shapes on the diamond DAG (the paper's
worst case for prescribed synchronization, Fig 1) at increasing sizes:

* ``prescribed`` start-up is exactly tasks + edges (the master declares
  everything); ``counted`` start-up is exactly tasks — both grow linearly.
* ``autodec`` start-up stays O(1) and its master does only the
  statically-computed root set (preschedule).
* ``tags1`` spatial peak tracks the edge count (one-use tags); ``tags2``
  tags are disposable only at completion, so its garbage gauge holds
  every producer's tag at the end while every other model drains to zero.
* ``autodec`` live counters peak at the frontier, not the graph.
"""
from __future__ import annotations

from repro.core.edt import MODELS, TiledTaskGraph, validate_order
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

SIZES = (4, 8, 12)


def _measurements():
    out = []
    for k in SIZES:
        g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
        params = {"K": k}
        m = g.materialize(params)
        runs = {}
        for name, fn in MODELS.items():
            r = fn(g, params, workers=4)
            validate_order(g, params, r)
            runs[name] = r.counters
        out.append((k, len(m.tasks), m.n_edges,
                    len(list(g.roots(params))), runs))
    return out


MEASURED = None


def _runs():
    global MEASURED
    if MEASURED is None:
        MEASURED = _measurements()
    return MEASURED


def test_prescribed_and_counted_startup_grow_with_tasks():
    for k, n, e, _, runs in _runs():
        assert runs["prescribed"].startup_ops == n + e
        assert runs["counted"].startup_ops == n
    startups = [runs["prescribed"].startup_ops for *_, runs in _runs()]
    assert startups == sorted(startups) and startups[0] < startups[-1]


def test_autodec_startup_is_o1_plus_roots():
    for k, n, e, roots, runs in _runs():
        assert runs["autodec"].startup_ops == 1      # O(1): gate never closes
        assert runs["autodec"].master_ops == roots   # preschedule = root set
        assert runs["autodec_nosrc"].startup_ops == 1
        assert runs["autodec_nosrc"].master_ops == n  # w/o src: all tasks
    # the root set, not the graph, sizes the master's work: on the
    # embarrassing program every task is a root and the master does N ops
    g = TiledTaskGraph(PROGRAMS["embarrassing"](), {"S": Tiling((1,))})
    r = MODELS["autodec"](g, {"N": 23}, workers=4)
    assert r.counters.master_ops == 23
    assert r.counters.startup_ops == 1


def test_tags1_spatial_peak_tracks_edges():
    peaks = []
    for k, n, e, _, runs in _runs():
        peak = runs["tags1"].spatial.peak
        # every edge becomes one one-use tag (+1 transient pending get)
        assert e - 1 <= peak <= e + 1
        assert runs["tags1"].spatial.total == 2 * e  # tag + pending get
        peaks.append(peak)
    assert peaks == sorted(peaks) and peaks[0] < peaks[-1]


def test_counted_spatial_is_tasks_autodec_is_frontier():
    for k, n, e, _, runs in _runs():
        assert runs["counted"].spatial.peak == n
        # autodec keeps only live frontier counters — far below n, and its
        # lifetime total still covers every task exactly once
        assert runs["autodec"].spatial.peak < n // 2
        assert runs["autodec"].spatial.total == n


def test_garbage_drains_to_zero_except_tags2():
    for k, n, e, _, runs in _runs():
        for name in ("prescribed", "tags1", "counted", "autodec",
                     "autodec_nosrc"):
            assert runs[name].garbage.cur == 0, name
            assert runs[name].inflight_deps.cur == 0, name
            assert runs[name].inflight_tasks.cur == 0, name
        # tags2 tags are only disposable at graph completion: every task
        # that produced a tag still holds it as garbage at the end
        assert runs["tags2"].garbage.cur == n - 1


def test_every_model_covered_and_validated():
    """``validate_order`` ran for every model at every size inside
    ``_measurements`` (exactly-once + dependence-respecting order); this
    pins that the registry was fully covered."""
    for *_, runs in _runs():
        assert set(runs) == set(MODELS)
