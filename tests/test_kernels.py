"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D", [
    (1, 128, 128, 2, 2, 64),     # MHA, single block
    (2, 256, 256, 4, 2, 64),     # GQA 2:1, multi-block
    (1, 384, 384, 3, 1, 128),    # GQA 3:1, D=128, odd block count
], ids=["mha128", "gqa256", "gqa384d128"])
def test_flash_attention_matches_ref(B, Sq, Skv, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, D), dtype)
    k = _rand(ks[1], (B, Skv, Hkv, D), dtype)
    v = _rand(ks[2], (B, Skv, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 128, 2, 64), jnp.float32)
    k = _rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,S,H,D,chunk", [
    (1, 32, 1, 8, 8),
    (2, 64, 2, 16, 16),
    (1, 128, 2, 64, 64),
], ids=["tiny", "small", "real64"])
def test_wkv6_matches_ref(B, S, H, D, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, S, H, D), dtype)
    v = _rand(ks[2], (B, S, H, D), dtype)
    # decays in (0,1), realistic RWKV range
    w = jax.nn.sigmoid(_rand(ks[3], (B, S, H, D), jnp.float32) - 1.0
                       ).astype(dtype)
    u = 0.1 * jax.random.normal(ks[4], (H, D), jnp.float32)
    out, st = ops.wkv6(r, k, v, w, u, chunk=chunk)
    want, want_st = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_st),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-3)


def test_wkv6_state_handoff():
    """Running two halves with the carried state == running the whole."""
    B, S, H, D = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = _rand(ks[0], (B, S, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, H, D), jnp.float32)
    v = _rand(ks[2], (B, S, H, D), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (B, S, H, D), jnp.float32))
    u = 0.1 * jax.random.normal(ks[4], (H, D), jnp.float32)
    full, _ = ops.wkv6(r, k, v, w, u, chunk=16)
    h = S // 2
    first, st = ops.wkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, chunk=16)
    second, _ = ops.wkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u,
                         init_state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([first, second], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 1, 16, 8, 8),
    (2, 64, 2, 32, 16, 16),
    (1, 128, 4, 64, 64, 32),
], ids=["tiny", "small", "real"])
def test_ssd_matches_ref(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = _rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32)) * 0.5
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = _rand(ks[3], (B, S, N), dtype)
    Cm = _rand(ks[4], (B, S, N), dtype)
    y, st = ops.ssd(x, dt.astype(jnp.float32), A, Bm, Cm, chunk=chunk)
    want, want_st = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_st),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3)


def test_ssd_state_handoff():
    B, S, H, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = _rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32)) * 0.5
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = _rand(ks[3], (B, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, S, N), jnp.float32)
    full, _ = ops.ssd(x, dt, A, Bm, Cm, chunk=16)
    h = S // 2
    y1, st = ops.ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], chunk=16)
    y2, _ = ops.ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:],
                    init_state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)


def test_model_ssd_scan_matches_kernel():
    """The model's pure-lax chunked SSD == the Pallas kernel == the ref."""
    from repro.models.ssm import _ssd_chunk_scan
    B, S, H, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = _rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32)) * 0.5
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = _rand(ks[3], (B, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, S, N), jnp.float32)
    y_model, st_model = _ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=16)
    y_ref, st_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_model), np.asarray(st_ref),
                               rtol=1e-3, atol=1e-3)
