"""EDT task graphs + synchronization models (paper §2, §4, Table 2)."""
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; deterministic tests still run
    from hypo_stub import HealthCheck, given, settings, st

from repro.core.edt import (MODELS, TiledTaskGraph, run_graph_threaded,
                            run_model, simulate_schedule, synthesize,
                            validate_order)
from repro.core.edt.codegen import emit_autodec, emit_prescribed, emit_tags
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

CASES = [
    ("stencil1d", {"S": Tiling((2, 3))}, {"T": 6, "N": 12}),
    ("seidel1d", {"S": Tiling((2, 2))}, {"T": 5, "N": 9}),
    ("jacobi2d", {"S": Tiling((2, 2, 2))}, {"T": 4, "N": 6}),
    ("matmul", {"S": Tiling((2, 2, 2))}, {"N": 5}),
    ("trisolv", {"S": Tiling((3, 2))}, {"N": 11}),
    ("lu_like", {"S": Tiling((2, 2, 2))}, {"N": 6}),
    ("pipeline", {"S": Tiling((2, 1))}, {"M": 8, "S": 4}),
    ("diamond", {"S": Tiling((2, 2))}, {"K": 8}),
    ("embarrassing", {"S": Tiling((4,))}, {"N": 17}),
]


_GRAPH_CACHE = {}


def _graph(prog, tilings):
    key = (prog, tuple(sorted((k, v.sizes) for k, v in tilings.items())))
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = TiledTaskGraph(PROGRAMS[prog](), tilings)
    return _GRAPH_CACHE[key]


@pytest.mark.parametrize("prog,tilings,params", CASES,
                         ids=[c[0] for c in CASES])
@pytest.mark.parametrize("model", list(MODELS))
def test_all_models_respect_dependences(prog, tilings, params, model):
    g = _graph(prog, tilings)
    res = run_model(model, g, params, workers=3)
    validate_order(g, params, res)


@pytest.mark.parametrize("prog,tilings,params", CASES,
                         ids=[c[0] for c in CASES])
def test_signal_count_consistency(prog, tilings, params):
    """Deadlock-freedom invariant: pred_count(t) equals the number of
    (dep, src) pairs that will signal t — even under inflation."""
    g = _graph(prog, tilings)
    incoming: dict = {}
    for t in g.tasks(params):
        incoming[t] = 0
    for t in g.tasks(params):
        for s in g.successors(t, params):
            incoming[s] += 1
    for t in g.tasks(params):
        assert g.pred_count(t, params) == incoming[t], t


@pytest.mark.parametrize("prog,tilings,params", CASES,
                         ids=[c[0] for c in CASES])
def test_graph_acyclic_and_roots(prog, tilings, params):
    g = _graph(prog, tilings)
    m = g.materialize(params)
    assert m.check_acyclic()
    roots = set(g.roots(params))
    assert roots == {t for t in m.tasks if m.pred_n[t] == 0}
    ws = synthesize(g, params)
    assert sum(len(lv) for lv in ws.levels) == len(m.tasks)
    # wavefront levels respect edges
    for t in m.tasks:
        for s in m.succ[t]:
            assert ws.level_of[s] > ws.level_of[t]


def test_table2_startup_overheads():
    """Prescribed startup grows with edges; counted with n; autodec is O(1)."""
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
    rows = {}
    for K in (4, 8):
        params = {"K": K}
        n = g.num_tasks(params)
        e = g.materialize(params).n_edges
        for m in ("prescribed", "counted", "autodec", "autodec_nosrc",
                  "tags1", "tags2"):
            res = run_model(m, g, params, workers=4)
            rows[(m, K)] = res.counters.summary()
        assert rows[("prescribed", K)]["startup_ops"] == n + e
        assert rows[("counted", K)]["startup_ops"] == n
        assert rows[("autodec", K)]["startup_ops"] == 1
        assert rows[("autodec_nosrc", K)]["startup_ops"] == 1
        assert rows[("tags1", K)]["startup_ops"] == 1
    # growth: prescribed startup scales ~4x when n scales 4x
    assert rows[("prescribed", 8)]["startup_ops"] > 3 * rows[("prescribed", 4)]["startup_ops"]


def test_table2_spatial_and_inflight():
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
    params = {"K": 10}
    n = g.num_tasks(params)
    pres = run_model("prescribed", g, params, workers=2).counters.summary()
    auto = run_model("autodec", g, params, workers=2).counters.summary()
    nosrc = run_model("autodec_nosrc", g, params, workers=2).counters.summary()
    t2 = run_model("tags2", g, params, workers=2).counters.summary()
    # prescribed holds all edges; autodec holds O(r·o) counters only
    assert pres["spatial_peak"] >= n  # ~2*K*(K-1) edges
    assert auto["spatial_peak"] <= 4 * 10  # O(r·o), r<=K, o=2
    assert auto["inflight_tasks_peak"] <= 10  # O(r): ready-only scheduling
    assert pres["inflight_tasks_peak"] == n
    # tags2 garbage grows with n; autodec's stays O(r) (fired counters whose
    # task hasn't started yet — bounded by the ready-queue depth, r<=K)
    assert t2["garbage_peak"] >= n - 1 - 2 * 10
    assert auto["garbage_peak"] <= 10
    # w/o src: spatial grows to O(n) (counters for everyone)
    assert nosrc["spatial_peak"] >= n * 0.5


def test_autodec_beats_prescribed_makespan():
    """§5.2 trend: with nontrivial per-op setup cost, autodec's O(1) startup
    wins on makespan for graphs with a dominator."""
    g = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})
    params = {"K": 10}
    pres = run_model("prescribed", g, params, workers=8, setup_cost=0.05)
    auto = run_model("autodec", g, params, workers=8, setup_cost=0.05)
    assert auto.counters.makespan < pres.counters.makespan


def test_threaded_autodec_exactly_once_and_ordered():
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((2, 2))})
    params = {"N": 12}
    import threading
    lock = threading.Lock()
    started_at = {}
    counter = [0]

    def body(t):
        with lock:
            started_at[t] = counter[0]
            counter[0] += 1

    order = run_graph_threaded(g, params, workers=4, body=body)
    tasks = list(g.tasks(params))
    assert sorted(order) == sorted(tasks)
    assert len(set(order)) == len(tasks)


@pytest.mark.parametrize("prog,tilings,params", CASES[:4],
                         ids=[c[0] for c in CASES[:4]])
def test_simulate_schedule_batched(prog, tilings, params):
    """Level-sized batches through Sim.make_ready_batch: every task runs
    once, levels run in order, and the makespan is the level-barrier sum."""
    import math
    g = TiledTaskGraph(PROGRAMS[prog](), tilings, backend="numpy")
    ws = synthesize(g, params)
    workers = 3
    sim = simulate_schedule(ws, workers=workers, task_dur=1.0)
    assert sorted(sim.exec_order) == sorted(t for lv in ws.levels for t in lv)
    assert sim.counters.makespan == sum(
        math.ceil(len(lv) / workers) for lv in ws.levels)
    # a task never starts before its level's predecessors completed
    pos = {t: i for i, t in enumerate(sim.exec_order)}
    for li in range(1, len(ws.levels)):
        first_this = min(pos[t] for t in ws.levels[li])
        last_prev = max(pos[t] for t in ws.levels[li - 1])
        assert first_this > last_prev


def test_make_ready_batch_matches_sequential_enqueue():
    from repro.core.edt import Sim
    runs = []
    s1 = Sim(workers=2, task_dur=1.0)
    for i in range(5):
        s1.make_ready(i, lambda i=i: runs.append(("a", i)))
    s1.run()
    s2 = Sim(workers=2, task_dur=1.0)
    s2.make_ready_batch((i, (lambda i=i: runs.append(("b", i)))) for i in range(5))
    s2.run()
    assert s1.exec_order == s2.exec_order
    assert s1.counters.makespan == s2.counters.makespan
    assert [i for t, i in runs if t == "a"] == [i for t, i in runs if t == "b"]


def test_sim_rejects_duplicate_ready_ids():
    """Exactly-once guard at the Sim layer: the counter-leak class the
    PR-4 threaded stress test caught (a task made ready twice) must be
    rejected at enqueue time, on every enqueue path."""
    import numpy as np

    from repro.core.edt import Sim

    # duplicate inside one make_ready_ids call
    sim = Sim(workers=2)
    with pytest.raises(ValueError, match="already made ready"):
        sim.make_ready_ids(np.asarray([0, 1, 1]), lambda: None)
    # duplicate across make_ready_ids calls
    sim = Sim(workers=2)
    sim.make_ready_ids(np.asarray([0, 1]), lambda: None)
    with pytest.raises(ValueError, match="already made ready"):
        sim.make_ready_ids(np.asarray([2, 1]), lambda: None)
    # duplicate across make_ready_batch calls and against make_ready
    sim = Sim(workers=2)
    sim.make_ready_batch([(("S", (0,)), lambda: None)])
    with pytest.raises(ValueError, match="already made ready"):
        sim.make_ready_batch([(("S", (0,)), lambda: None)])
    sim = Sim(workers=2)
    sim.make_ready("t0", lambda: None)
    with pytest.raises(ValueError, match="already made ready"):
        sim.make_ready("t0", lambda: None)
    # mixed paths share one guard: an id enqueued via make_ready is also
    # rejected when it reappears in a batch of ids
    sim = Sim(workers=2)
    sim.make_ready(3, lambda: None)
    with pytest.raises(ValueError, match="already made ready"):
        sim.make_ready_ids(np.asarray([3]), lambda: None)
    # distinct keys still flow through untouched
    sim = Sim(workers=2)
    sim.make_ready_ids(np.asarray([0, 1, 2]), lambda: None)
    sim.run()
    assert sim.exec_order == [0, 1, 2]


def test_codegen_emission():
    g = TiledTaskGraph(PROGRAMS["pipeline"](), {"S": Tiling((2, 1))})
    pres = emit_prescribed(g)
    assert "task_init" in pres and "declare_dependence" in pres
    tags = emit_tags(g, method=2)
    assert "put(tag(iT))" in tags
    auto = emit_autodec(g)
    assert "autodec(" in auto and "pred_count_S" in auto
    assert "enumerator" in auto  # pipeline deps are rectangular


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ts=st.tuples(st.integers(1, 3), st.integers(1, 3)),
       n=st.integers(4, 9))
def test_property_trisolv_any_tiling_consistent(ts, n):
    """Signal-count consistency holds for arbitrary tilings/params."""
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling(ts)})
    params = {"N": n}
    incoming = {t: 0 for t in g.tasks(params)}
    for t in g.tasks(params):
        for s in g.successors(t, params):
            incoming[s] += 1
    for t, c in incoming.items():
        assert g.pred_count(t, params) == c
    res = run_model("autodec", g, params, workers=2)
    validate_order(g, params, res)
