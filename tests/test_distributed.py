"""Distributed counted-sync suite: rank partition, message decrements,
exactly-once delivery, and fault recovery (``docs/distributed.md``).

The contract under test:

* the rank partition covers the graph exactly — every counter, every edge,
  and every cross-rank decrement accounted once;
* for seeded programs × rank counts × engines × transports, the union of
  per-rank frontiers is byte-identical to the single-host oracles
  (``schedule_from_graph`` levels, ``simulate_indexed`` execution order,
  ``DeviceExecutor`` discover frontiers);
* duplicate message batches are admitted exactly once (sequence-numbered
  mailboxes), so replayed traffic never corrupts a counter;
* an injected rank crash or lost decrement batch fails the attempt
  *visibly* (``RankFailureError`` / ``StallError`` with the undrained
  counters named) and recovers byte-identically under a ``RetryPolicy``;
* the ``EDT_DIST_ACCEPT`` gate runs the ≥10M-task jacobi2d acceptance
  across 2 ranks against the single-host sweep.
"""
from __future__ import annotations

import os
from collections import deque

import numpy as np
import pytest

from repro.core.edt import (DeviceExecutor, ExecutionConfig, Fault,
                            FaultPlan, InjectedRankCrash, MESSAGE_LOSS,
                            Mailbox, MsgBatch, RANK_CRASH, RankEngine,
                            RankFailureError, RetryPolicy, Session,
                            StallError, TiledTaskGraph, partition_graph,
                            plan_ranks, run_distributed,
                            schedule_from_graph, simulate_indexed)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

CASES = [
    ("jacobi2d", (2, 2, 2), {"T": 8, "N": 24}),
    ("trisolv", (2, 2), {"N": 20}),
    ("seidel1d", (2, 2), {"T": 10, "N": 30}),
    ("diamond", (2, 2), {"K": 12}),
    ("pipeline", (1, 1), {"M": 12, "S": 5}),
]

RETRY = RetryPolicy(max_retries=3, base_delay=0.001)


def _ig(name, tiles, params):
    g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                       backend="numpy")
    return g.index_graph(params)


def assert_matches_host(ig, run, sched=None) -> None:
    """The differential property: merged rank frontiers == host frontiers,
    byte for byte, and the Sim replays the identical order."""
    if sched is None:
        sched = schedule_from_graph(ig)
    assert run.depth == sched.depth
    for got, want in zip(run.levels, sched.levels):
        assert got.dtype == want.dtype
        assert np.array_equal(got, want)
    assert run.level_of.tobytes() == sched.level_of.tobytes()
    sim = simulate_indexed(sched, workers=3)
    assert np.array_equal(run.exec_order, np.asarray(sim.exec_order))


# ---------------------------------------------------------------- partition
def test_partition_covers_graph_exactly():
    """Counters, local edges, cross edges, expected decrements: each
    accounted exactly once across the rank slices."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    for ranks in (1, 2, 3, 5):
        slices = partition_graph(ig, ranks)
        bounds = plan_ranks(ig.n, ranks)
        assert bounds[0] == 0 and bounds[-1] == ig.n
        assert np.array_equal(
            np.concatenate([sl.indeg for sl in slices]), ig.pred_n)
        n_local = sum(int(sl.l_tgt.size) for sl in slices)
        n_cross = sum(int(sl.r_tgt.size) for sl in slices)
        assert n_local + n_cross == ig.n_edges
        # every expected decrement has exactly one sender
        assert sum(sl.expected_in for sl in slices) == n_cross
        for sl in slices:
            assert sl.l_indptr[-1] == sl.l_tgt.size
            assert sl.r_indptr[-1] == sl.r_tgt.size
            if sl.l_tgt.size:
                assert sl.l_tgt.min() >= 0 and sl.l_tgt.max() < sl.n_local
            if sl.r_tgt.size:   # remote targets never land in-range
                assert ((sl.r_tgt < sl.lo) | (sl.r_tgt >= sl.hi)).all()


def test_plan_ranks_is_deterministic_divmod():
    bounds = plan_ranks(10, 4)
    assert bounds.tolist() == [0, 3, 6, 8, 10]
    assert np.array_equal(bounds, plan_ranks(10, 4))
    with pytest.raises(ValueError):
        plan_ranks(10, 0)


# ------------------------------------------------------------- differential
@pytest.mark.parametrize("name,tiles,params", CASES)
@pytest.mark.parametrize("ranks", [1, 2, 4])
def test_inline_numpy_matches_single_host(name, tiles, params, ranks):
    ig = _ig(name, tiles, params)
    run = run_distributed(ig, ranks=ranks, engine="numpy",
                          transport="inline")
    assert_matches_host(ig, run)
    stats = run.rank_stats
    assert sum(s.started for s in stats) == ig.n
    assert sum(s.msgs_in for s in stats) == sum(s.msgs_out for s in stats)
    assert not any(s.duplicates for s in stats)


@pytest.mark.parametrize("ranks", [2, 3])
def test_inline_device_engine_matches_device_executor(ranks):
    """The device rank engine (the single-host jitted decrement step,
    per rank) agrees with both the host oracle and the single-host
    ``DeviceExecutor`` discover sweep."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    run = run_distributed(ig, ranks=ranks, engine="device",
                          transport="inline")
    assert_matches_host(ig, run)
    dev = DeviceExecutor(ig).run()
    assert np.array_equal(run.exec_order, dev.exec_order)
    assert run.level_of.tobytes() == dev.level_of.tobytes()


def test_inline_pallas_engine_matches():
    ig = _ig("trisolv", (2, 2), {"N": 20})
    run = run_distributed(ig, ranks=2, engine="device", transport="inline",
                          use_pallas=True)
    assert_matches_host(ig, run)


@pytest.mark.parametrize("ranks", [2, 4])
def test_process_transport_matches_single_host(ranks):
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    run = run_distributed(ig, ranks=ranks, engine="numpy",
                          transport="processes", timeout=30.0)
    assert_matches_host(ig, run)
    assert run.attempts == 0


def test_process_transport_spawn_safe():
    """The rank worker is a module-level entry point: the run survives the
    spawn start method (no inherited interpreter state)."""
    ig = _ig("trisolv", (2, 2), {"N": 20})
    run = run_distributed(ig, ranks=2, engine="numpy",
                          transport="processes", timeout=30.0,
                          start_method="spawn")
    assert_matches_host(ig, run)


def test_more_ranks_than_wavefronts():
    """Degenerate splits (nearly one task per rank) still merge exactly."""
    ig = _ig("trisolv", (2, 2), {"N": 8})
    run = run_distributed(ig, ranks=min(8, ig.n), transport="inline")
    assert_matches_host(ig, run)


def test_session_distributed_uses_cached_graph():
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((2, 2))},
                       backend="numpy")
    with Session() as s:
        ig = s.index_graph(g, {"N": 20})
        hits0 = s.cache.info()["hits"]
        run = s.distributed(g, {"N": 20}, ranks=2, transport="inline")
        assert s.cache.info()["hits"] > hits0    # served from the cache
        assert_matches_host(ig, run)


def test_engine_transport_validation():
    ig = _ig("trisolv", (2, 2), {"N": 8})
    with pytest.raises(ValueError, match="inline transport"):
        run_distributed(ig, ranks=2, engine="device", transport="processes")
    with pytest.raises(ValueError, match="transport"):
        run_distributed(ig, ranks=2, transport="telepathy")
    with pytest.raises(ValueError, match="engine"):
        run_distributed(ig, ranks=2, engine="abacus", transport="inline")


# ------------------------------------------------------------- exactly-once
def test_mailbox_admits_each_sequence_once():
    mb = Mailbox(ranks=2)
    b0 = MsgBatch(src=1, dst=0, seq=0, tgt=np.array([3, 4]),
                  lvl=np.array([1, 1]))
    b1 = MsgBatch(src=1, dst=0, seq=1, tgt=np.array([5]), lvl=np.array([2]))
    assert mb.admit(b0) and mb.admit(b1)
    assert not mb.admit(b0) and not mb.admit(b1)   # replays dropped
    assert mb.duplicates == 2
    assert mb.admitted_msgs == 3 and mb.admitted_batches == 2


def test_duplicate_batches_never_double_decrement():
    """Adversarial fabric: every batch delivered twice.  The mailboxes
    drop every replay, counters drain exactly once, and the merged
    frontiers stay byte-identical to the oracle."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    slices = partition_graph(ig, 2)
    engines = [RankEngine(sl) for sl in slices]
    queues = [deque(), deque()]
    while True:
        for eng, q in zip(engines, queues):
            while q:
                eng.apply(q.popleft())
        if all(e.done for e in engines):
            break
        moved = any(e.pending_size for e in engines)
        for eng in engines:
            for b in eng.superstep():
                queues[b.dst].append(b)
                queues[b.dst].append(MsgBatch(        # the replay
                    src=b.src, dst=b.dst, seq=b.seq,
                    tgt=b.tgt.copy(), lvl=b.lvl.copy()))
        assert moved or any(queues), "stalled under duplicate delivery"
    sent = sum(e.batches_out for e in engines)
    assert sent > 0
    assert sum(e.mail.duplicates for e in engines) == sent
    assert sum(e.mail.admitted_batches for e in engines) == sent
    level_of = np.empty(ig.n, dtype=np.int64)
    for sl, eng in zip(slices, engines):
        level_of[sl.lo:sl.hi] = eng.level
    assert level_of.tobytes() == \
        schedule_from_graph(ig).level_of.tobytes()


# ---------------------------------------------------------- fault recovery
@pytest.mark.parametrize("transport", ["inline", "processes"])
def test_rank_crash_recovers_byte_identical(transport):
    """A rank dying mid-run is retried; the recovered run is byte-identical
    to a fault-free one and the plan logged every fire."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    plan = FaultPlan(faults=(Fault(kind=RANK_CRASH, index=1, times=2),))
    assert plan.recoverable(RETRY.max_retries)
    cfg = ExecutionConfig(faults=plan, recovery=RETRY)
    run = run_distributed(ig, ranks=2, transport=transport, timeout=15.0,
                          config=cfg)
    assert run.attempts == 2
    assert [f[0] for f in plan.fired] == [RANK_CRASH, RANK_CRASH]
    assert_matches_host(ig, run)


def test_hard_rank_crash_kills_process_and_recovers():
    """``hard=True`` takes the rank process down with ``os._exit``; the
    driver sees the dead process, fails the attempt, and the retry is
    byte-identical."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    plan = FaultPlan(faults=(
        Fault(kind=RANK_CRASH, index=0, times=1, hard=True),))
    cfg = ExecutionConfig(faults=plan, recovery=RETRY)
    run = run_distributed(ig, ranks=2, transport="processes", timeout=15.0,
                          config=cfg)
    assert run.attempts == 1
    assert_matches_host(ig, run)


@pytest.mark.parametrize("transport", ["inline", "processes"])
def test_message_loss_stalls_then_recovers(transport):
    """A dropped decrement batch leaves ``received < expected_in``: the
    attempt surfaces as a stall (never a hang, never a wrong answer) and
    the retry redelivers."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    plan = FaultPlan(faults=(
        Fault(kind=MESSAGE_LOSS, round=0, index=1, times=1),))
    cfg = ExecutionConfig(faults=plan, recovery=RETRY)
    run = run_distributed(ig, ranks=2, transport=transport, timeout=2.0,
                          config=cfg)
    assert run.attempts == 1
    assert plan.fired and plan.fired[0][0] == MESSAGE_LOSS
    assert_matches_host(ig, run)


def test_message_loss_without_policy_raises_stall_report():
    """No retry policy: the loss is a diagnosis, not a hang — the report
    names the undrained counters and the missing decrement count."""
    ig = _ig("jacobi2d", (2, 2, 2), {"T": 8, "N": 24})
    plan = FaultPlan(faults=(
        Fault(kind=MESSAGE_LOSS, round=0, index=1, times=1),))
    with pytest.raises(StallError) as exc:
        run_distributed(ig, ranks=2, transport="inline",
                        config=ExecutionConfig(faults=plan))
    report = exc.value.report
    assert report.undrained
    assert "decrement" in report.note
    assert report.to_json()          # serializes for the CI artifact


def test_crash_beyond_retry_budget_raises():
    ig = _ig("trisolv", (2, 2), {"N": 20})
    plan = FaultPlan(faults=(Fault(kind=RANK_CRASH, index=0, times=5),))
    assert not plan.recoverable(RETRY.max_retries)
    with pytest.raises(InjectedRankCrash):
        run_distributed(ig, ranks=2, transport="inline",
                        config=ExecutionConfig(
                            faults=plan,
                            recovery=RetryPolicy(max_retries=1,
                                                 base_delay=0.001)))


def test_dead_rank_without_policy_raises_failure_report():
    ig = _ig("trisolv", (2, 2), {"N": 20})
    plan = FaultPlan(faults=(
        Fault(kind=RANK_CRASH, index=0, times=1, hard=True),))
    with pytest.raises(RankFailureError) as exc:
        run_distributed(ig, ranks=2, transport="processes", timeout=15.0,
                        config=ExecutionConfig(faults=plan))
    assert exc.value.report.failed
    assert exc.value.report.to_json()


# ------------------------------------------------------------- acceptance
@pytest.mark.skipif(not os.environ.get("EDT_DIST_ACCEPT"),
                    reason="set EDT_DIST_ACCEPT=1 for the ≥10M-task "
                           "distributed acceptance run")
def test_ten_million_task_acceptance():
    """The acceptance run: a ≥10M-task jacobi2d graph executes across 2
    ranks (process transport, one OS process per rank) with frontiers
    byte-identical to the single-host ``simulate_indexed`` sweep."""
    g = TiledTaskGraph(PROGRAMS["jacobi2d"](), {"S": Tiling((2, 2, 2))},
                       backend="compiled")
    ig = g.index_graph({"T": 32, "N": 1600})
    assert ig.n >= 10_000_000
    sched = schedule_from_graph(ig)
    run = run_distributed(ig, ranks=2, engine="numpy",
                          transport="processes", timeout=600.0)
    assert run.level_of.tobytes() == sched.level_of.tobytes()
    for got, want in zip(run.levels, sched.levels):
        assert np.array_equal(got, want)
    sim = simulate_indexed(sched, workers=8)
    assert np.array_equal(run.exec_order, np.asarray(sim.exec_order))
    assert sum(s.started for s in run.rank_stats) == ig.n
