"""ExecutionConfig / Session API suite: shim equivalence and deprecation.

Every graph-level entry point accepts ``config=``/``session=``; the
legacy per-call kwargs still work but warn.  The repo's own pytest config
escalates the shim's DeprecationWarning to an error
(``filterwarnings = ["error:legacy execution kwargs"]``), so these tests
double as the CI gate: any in-repo caller still on the old kwargs fails
the suite, while ``pytest.warns`` below proves the shim itself stays
functional for out-of-tree callers.
"""
from __future__ import annotations

from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest

from repro.core.edt import (DeviceExecutor, ExecutionConfig, Session,
                            TiledTaskGraph, synthesize, synthesize_indexed)
from repro.core.edt.config import (DEFAULT_CONFIG, UNSET, CachePolicy,
                                   resolve_execution)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

PARAMS = {"N": 20}


@pytest.fixture(scope="module")
def pool():
    p = ProcessPoolExecutor(max_workers=2)
    p.submit(int, 0).result()
    yield p
    p.shutdown()


def _graph(backend="numpy"):
    return TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((4, 4))},
                          backend=backend)


# ========================================================== resolution
def test_resolve_defaults():
    cfg, sess = resolve_execution(None, None)
    assert cfg is DEFAULT_CONFIG and sess is None


def test_resolve_legacy_builds_equivalent_config():
    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        cfg, sess = resolve_execution(
            None, None, legacy=dict(shards=3, parallel=UNSET, pool=UNSET,
                                    faults=UNSET, recovery=UNSET))
    assert sess is None
    assert cfg.shards == 3 and cfg.resolve_shards() == 3


def test_resolve_rejects_mixing():
    with pytest.raises(TypeError, match="not both"):
        resolve_execution(ExecutionConfig(), None, legacy=dict(shards=2))
    with pytest.raises(TypeError, match="not both"):
        resolve_execution(ExecutionConfig(), Session())


def test_default_call_does_not_warn():
    """Omitting every kwarg must not trip the shim (UNSET sentinel, not
    None, distinguishes "not passed")."""
    import warnings
    g = _graph()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ig = synthesize_indexed(g, PARAMS)[0]
    assert ig.n > 0


def test_parallel_resolves_to_cpu_count():
    import os
    assert ExecutionConfig(parallel=True).resolve_shards() == \
        (os.cpu_count() or 1)
    assert ExecutionConfig(parallel=True, shards=2).resolve_shards() == 2
    assert ExecutionConfig().resolve_shards() == 0


# ======================================================== shim warning
def test_legacy_kwargs_warn_and_match_config_results(pool):
    g = _graph()
    cfg = ExecutionConfig(shards=2, pool=pool)
    ref = g.index_graph(PARAMS, config=cfg)
    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        legacy = g.index_graph(PARAMS, shards=2, pool=pool)
    assert np.array_equal(legacy.edge_src, ref.edge_src)
    assert np.array_equal(legacy.edge_tgt, ref.edge_tgt)
    assert np.array_equal(legacy.pred_n, ref.pred_n)

    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        m = g.materialize(PARAMS, shards=2, pool=pool)
    assert m.succ == g._materialize_cfg(PARAMS, cfg).succ

    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        r = list(g.roots(PARAMS, shards=2, pool=pool))
    assert r == list(g.roots(PARAMS, config=cfg))

    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        ws = synthesize(g, PARAMS, shards=2, pool=pool)
    assert ws.levels == synthesize(g, PARAMS, config=cfg).levels

    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        igl, schedl = synthesize_indexed(g, PARAMS, shards=2, pool=pool)
    igc, schedc = synthesize_indexed(g, PARAMS, config=cfg)
    assert np.array_equal(schedl.level_of, schedc.level_of)

    ig = g.index_graph(PARAMS)
    with pytest.warns(DeprecationWarning, match="legacy execution kwargs"):
        run = DeviceExecutor(ig, faults=None, shards=UNSET).run()
    assert run.counters.tasks_finished == ig.n


def test_mixing_legacy_and_config_is_typeerror():
    g = _graph()
    with pytest.raises(TypeError, match="not both"):
        g.index_graph(PARAMS, shards=2, config=ExecutionConfig())
    with pytest.raises(TypeError, match="not both"):
        g.roots(PARAMS, pool=None, session=Session())


# ============================================================= session
def test_session_products_match_direct_calls():
    g = _graph()
    with Session(ExecutionConfig(backend="numpy")) as s:
        ig = s.index_graph(g, PARAMS)
        ref = g.index_graph(PARAMS)
        assert np.array_equal(ig.edge_src, ref.edge_src)
        assert list(s.roots(g, PARAMS)) == list(g.roots(PARAMS))
        assert s.synthesize(g, PARAMS).levels == synthesize(g, PARAMS).levels
        # warm: the same object comes back, and session= reuses it
        assert s.index_graph(g, PARAMS) is ig
        assert g.index_graph(PARAMS, session=s) is ig


def test_session_executor_runs_from_cached_packed():
    g = _graph()
    with Session() as s:
        run = s.executor(g, PARAMS).run()
        assert run.counters.tasks_finished == s.index_graph(g, PARAMS).n
        run2 = s.executor(g, PARAMS, replay=False).run()
        assert run2.counters.tasks_finished == run.counters.tasks_finished


def test_session_overrides_and_cache_policy():
    s = Session(cache=CachePolicy(max_entries=1))
    assert s.config.cache.max_entries == 1
    assert s.cache.policy.max_entries == 1
    s.close()


def test_session_graph_uses_configured_backend():
    with Session(ExecutionConfig(backend="fraction")) as s:
        g = s.graph(PROGRAMS["trisolv"](), {"S": Tiling((4, 4))})
        assert g.backend == "fraction"
