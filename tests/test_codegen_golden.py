"""Golden snapshots of the §4 generated-code emitters.

``core/edt/codegen.py`` renders the paper's Figures 3/4/5 as pseudo-C;
until now nothing covered it, so a refactor of ``LoopNest.pretty_loops``
(or of the counting-strategy heuristic the autodec emitter reports) could
silently change every emitted form.  These tests pin the full output for
two shapes — the dense diamond grid (enumerator-strategy counters) and the
skewed Jacobi-1D stencil with a non-unit tiling (loop-strategy counters,
``ceild``/``floord`` bounds with real divisors).

The snapshots live in ``tests/golden/codegen_<program>.txt``.  On an
*intentional* emitter change, regenerate them with

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_codegen_golden.py

and review the diff like any other code change.
"""
from __future__ import annotations

import os
import pathlib

import pytest

from repro.core.edt import TiledTaskGraph
from repro.core.edt.codegen import (emit_autodec, emit_fused,
                                    emit_prescribed, emit_tags)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS
from repro.kernels.stencils import SPECS

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
CASES = {"diamond": (1, 1), "stencil1d": (2, 4)}


def _render(name: str) -> str:
    g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(CASES[name])})
    parts = [
        emit_prescribed(g), "",
        emit_tags(g, method=2), "",
        emit_tags(g, method=1), "",
        emit_autodec(g), "",
    ]
    if name in SPECS:   # fused form exists only for programs with a body
        parts += [emit_fused(g), ""]
    return "\n".join(parts)


@pytest.mark.parametrize("name", sorted(CASES))
def test_codegen_matches_golden(name):
    path = GOLDEN_DIR / f"codegen_{name}.txt"
    text = _render(name)
    if os.environ.get("REGEN_GOLDEN"):
        path.write_text(text)
    golden = path.read_text()
    assert text == golden, (
        f"emitted pseudo-C for {name!r} drifted from {path}; if the change "
        f"is intentional, regenerate with REGEN_GOLDEN=1 and review the diff")


@pytest.mark.parametrize("name", sorted(CASES))
def test_codegen_is_deterministic(name):
    """Two independent graph builds emit byte-identical code (no dict-order
    or cache-state leakage into the rendered loops)."""
    assert _render(name) == _render(name)


def test_autodec_reports_both_strategies():
    """The golden pair intentionally spans both §4.3 counting strategies."""
    d = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling(CASES["diamond"])})
    s = TiledTaskGraph(PROGRAMS["stencil1d"](),
                       {"S": Tiling(CASES["stencil1d"])})
    assert set(d.pred_count_strategies().values()) == {"enumerator"}
    assert set(s.pred_count_strategies().values()) == {"loop"}
    assert "closed_form" in emit_autodec(d)
    assert "n++;" in emit_autodec(s)


def test_fused_emitter_requires_a_body():
    """Programs with no registered stencil body have no fused form."""
    d = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling(CASES["diamond"])})
    with pytest.raises(ValueError, match="no stencil body"):
        emit_fused(d)


def test_fused_emitter_reflects_the_spec():
    """Sequential dims render as loops, parallel dims as vmap, and every
    tap of the body appears with its parity buffer."""
    s = TiledTaskGraph(PROGRAMS["seidel1d"](),
                       {"S": Tiling(CASES["stencil1d"])})
    text = emit_fused(s)
    assert "Gauss-Seidel dim: sequential" in text
    assert text.count("acc +=") == len(SPECS["seidel1d"].taps)
    assert "u[p, s + (-1,)]" in text      # dt=0 tap reads the same parity
    assert "u[1-p, s + (1,)]" in text     # dt=1 tap reads the other parity
