"""Golden snapshots of the §4 generated-code emitters.

``core/edt/codegen.py`` renders the paper's Figures 3/4/5 as pseudo-C;
until now nothing covered it, so a refactor of ``LoopNest.pretty_loops``
(or of the counting-strategy heuristic the autodec emitter reports) could
silently change every emitted form.  These tests pin the full output for
two shapes — the dense diamond grid (enumerator-strategy counters) and the
skewed Jacobi-1D stencil with a non-unit tiling (loop-strategy counters,
``ceild``/``floord`` bounds with real divisors).

The snapshots live in ``tests/golden/codegen_<program>.txt``.  On an
*intentional* emitter change, regenerate them with

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_codegen_golden.py

and review the diff like any other code change.
"""
from __future__ import annotations

import os
import pathlib

import pytest

from repro.core.edt import TiledTaskGraph
from repro.core.edt.codegen import emit_autodec, emit_prescribed, emit_tags
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
CASES = {"diamond": (1, 1), "stencil1d": (2, 4)}


def _render(name: str) -> str:
    g = TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(CASES[name])})
    return "\n".join([
        emit_prescribed(g), "",
        emit_tags(g, method=2), "",
        emit_tags(g, method=1), "",
        emit_autodec(g), "",
    ])


@pytest.mark.parametrize("name", sorted(CASES))
def test_codegen_matches_golden(name):
    path = GOLDEN_DIR / f"codegen_{name}.txt"
    text = _render(name)
    if os.environ.get("REGEN_GOLDEN"):
        path.write_text(text)
    golden = path.read_text()
    assert text == golden, (
        f"emitted pseudo-C for {name!r} drifted from {path}; if the change "
        f"is intentional, regenerate with REGEN_GOLDEN=1 and review the diff")


@pytest.mark.parametrize("name", sorted(CASES))
def test_codegen_is_deterministic(name):
    """Two independent graph builds emit byte-identical code (no dict-order
    or cache-state leakage into the rendered loops)."""
    assert _render(name) == _render(name)


def test_autodec_reports_both_strategies():
    """The golden pair intentionally spans both §4.3 counting strategies."""
    d = TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling(CASES["diamond"])})
    s = TiledTaskGraph(PROGRAMS["stencil1d"](),
                       {"S": Tiling(CASES["stencil1d"])})
    assert set(d.pred_count_strategies().values()) == {"enumerator"}
    assert set(s.pred_count_strategies().values()) == {"loop"}
    assert "closed_form" in emit_autodec(d)
    assert "n++;" in emit_autodec(s)
