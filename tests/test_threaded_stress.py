"""ThreadedAutodec stress: the Fig-1 creation race stays resolved.

A wide diamond mesh (every interior task has two predecessors that can
complete concurrently) is executed repeatedly at worker counts well above
the core count.  The atomic get-or-create-then-decrement must yield
exactly-once task creation and an execution order that respects every
dependence — under real thread interleavings, not the simulator.
"""
from __future__ import annotations

import threading

from repro.core.edt import (ThreadedAutodec, TiledTaskGraph,
                            run_graph_threaded)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

K = 20          # 400 tasks, 760 edges, width up to 20
REPEATS = 4
WORKERS = (8, 32)


def _graph():
    return TiledTaskGraph(PROGRAMS["diamond"](), {"S": Tiling((1, 1))})


def test_diamond_mesh_exactly_once_and_topological():
    g = _graph()
    params = {"K": K}
    m = g.materialize(params)
    all_tasks = set(m.tasks)
    for workers in WORKERS:
        for _ in range(REPEATS):
            order = run_graph_threaded(g, params, workers=workers)
            assert len(order) == len(all_tasks), "task lost or duplicated"
            assert set(order) == all_tasks
            pos = {t: i for i, t in enumerate(order)}
            for t, succs in m.succ.items():
                for s in succs:
                    assert pos[s] > pos[t], f"dependence violated {t}->{s}"


def test_counter_table_drains_and_single_creator():
    """Every counter is created once, fires once, and is GC'd at schedule
    time; concurrent autodecs on a shared successor never double-fire."""
    g = _graph()
    params = {"K": 12}
    created = []
    lock = threading.Lock()

    def counted_pred(t):
        with lock:
            created.append(t)
        return g.pred_count(t, params)

    rt = ThreadedAutodec(
        pred_count=counted_pred,
        successors=lambda t: list(g.successors(t, params)),
        body=lambda t: None,
        workers=16,
    )
    rt.preschedule_all(g.tasks(params))
    assert rt.wait(timeout=120)
    rt.shutdown()
    assert not rt.errors
    n = g.num_tasks(params)
    assert len(rt.executed) == n
    # one creation per task: the get-or-create is atomic
    assert len(created) == len(set(created)) == n
    assert not rt._counters, "all counters must be GC'd at schedule time"


def test_stress_with_failing_body_does_not_wedge():
    """A raising task body must not deadlock the runtime (quiesce + error
    surfaced), even at high concurrency."""
    g = _graph()
    params = {"K": 8}
    bad = ("S", (3, 3))

    def body(t):
        if t == bad:
            raise RuntimeError("boom")

    rt = ThreadedAutodec(
        pred_count=lambda t: g.pred_count(t, params),
        successors=lambda t: list(g.successors(t, params)),
        body=body,
        workers=24,
    )
    rt.preschedule_all(g.tasks(params))
    assert rt.wait(timeout=120), "runtime wedged on task failure"
    rt.shutdown()
    assert [k for k, _ in rt.errors] == [bad]
    # the failed task never signalled its successors, so the graph below
    # it stays unexecuted — but nothing ran twice
    assert len(rt.executed) == len(set(rt.executed))
    assert bad not in rt.executed
