"""Unit + property tests for the exact polyhedral engine (paper §3)."""
from fractions import Fraction as F

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; deterministic tests still run
    from hypo_stub import HealthCheck, given, settings, st

from repro.core.poly import (LoopNest, Polyhedron, Tiling, lp_feasible,
                             lp_max, lp_min, make_counting_function,
                             minkowski_sum_box_exact, project_out,
                             tile_dependence, tile_dependence_projection,
                             tile_domain)

# ----------------------------------------------------------------- LP


def test_lp_basic():
    rows = [(F(1), F(0), F(0)), (F(-1), F(0), F(10)),
            (F(0), F(1), F(-2)), (F(0), F(-1), F(5))]
    assert lp_max(rows, 2, [1, 1]).value == 15
    assert lp_min(rows, 2, [1, 1]).value == 2
    assert lp_feasible(rows, 2)
    assert not lp_feasible(rows + [(F(1), F(0), F(-20))], 2)


def test_lp_unbounded():
    rows = [(F(1), F(0))]  # x >= 0
    assert lp_max(rows, 1, [1]).status == "unbounded"
    assert lp_min(rows, 1, [1]).value == 0


def test_lp_negative_rhs_phase1():
    # x >= 5 (written as x - 5 >= 0 -> needs phase 1 after standardization)
    rows = [(F(1), F(-5)), (F(-1), F(9))]
    r = lp_min(rows, 1, [1])
    assert r.status == "optimal" and r.value == 5


# ------------------------------------------------------------ polyhedron


def tri(N=None):
    """0 <= i <= j <= N-1 with N symbolic."""
    return Polyhedron.from_ineqs(("i", "j"), ("N",), [
        (1, 0, 0, 0), (-1, 1, 0, 0), (0, -1, 1, -1)])


def test_membership_and_empty():
    P = tri()
    assert P.contains_point((0, 0), (4,))
    assert P.contains_point((2, 3), (4,))
    assert not P.contains_point((3, 2), (4,))
    assert not P.is_empty()
    assert P.add_ineq((1, 0, 0, -100)).add_ineq((-1, 0, 0, 50)).is_empty()


def test_projection_triangle():
    P = tri()
    Q = project_out(P, [1])  # exists j
    assert Q.contains_point((0,), (4,)) and Q.contains_point((3,), (4,))
    assert not Q.contains_point((4,), (4,))


def test_equalities_gaussian_elim():
    # line i = j inside a box, project out j -> segment
    P = Polyhedron.from_ineqs(("i", "j"), (), [
        (1, 0, 0), (-1, 0, 5), (0, 1, 0), (0, -1, 5)], eqs=[(1, -1, 0)])
    Q = project_out(P, [1])
    lo, hi = Q.dim_bounds(0)
    assert (lo, hi) == (0, 5)


def test_scanning_matches_bruteforce():
    P = tri()
    pts = set(LoopNest(P).iterate({"N": 5}))
    brute = {(i, j) for i in range(5) for j in range(5)
             if 0 <= i <= j <= 4}
    assert pts == brute
    assert LoopNest(P).count({"N": 5}) == len(brute)


def test_scanning_guards():
    # family: {i : 0 <= i < N and N <= 3}; for N=5 it must be empty
    P = Polyhedron.from_ineqs(("i",), ("N",), [
        (1, 0, 0), (-1, 1, -1), (0, -1, 3)])
    nest = LoopNest(P)
    assert nest.count({"N": 5}) == 0
    assert nest.count({"N": 3}) == 3


# -------------------------------------------------------- §3 compression

def _dep_example():
    """(i,j) -> (i, j+1) inside the triangle; dims (is, js, it, jt)."""
    P = tri()
    src = P.rename(dim_names=("is_", "js")).add_dims(("it", "jt"))
    tgt = P.rename(dim_names=("it", "jt")).add_dims(("is_", "js"), front=True)
    return (src.intersect(tgt)
            .add_eq((1, 0, -1, 0, 0, 0))
            .add_eq((0, 1, 0, -1, 0, 1)))


@pytest.mark.parametrize("gs,gt", [((2, 2), (2, 2)), ((2, 3), (2, 3)),
                                   ((1, 4), (1, 4)), ((3, 1), (3, 1))])
def test_compression_equals_projection(gs, gt):
    """THE theorem: compression+exact-sum == FM projection (rationally)."""
    delta = _dep_example()
    a = tile_dependence(delta, 2, Tiling(gs), Tiling(gt), method="exact")
    b = tile_dependence_projection(delta, 2, Tiling(gs), Tiling(gt))
    assert a.equals(b)


@pytest.mark.parametrize("g", [(2, 2), (3, 2), (4, 1)])
def test_inflation_superset_and_same_integers(g):
    delta = _dep_example()
    infl = tile_dependence(delta, 2, Tiling(g), Tiling(g), method="inflate")
    exact = tile_dependence(delta, 2, Tiling(g), Tiling(g), method="exact")
    assert infl.contains(exact)
    # constraint count: inflation must not add constraints (no vertex blowup)
    assert len(infl.ineqs) <= len(exact.ineqs) + len(exact.eqs) * 2 + 4


def test_tile_domain_integers():
    P = tri()
    td = tile_domain(P, Tiling((2, 2)))
    tiles = set(LoopNest(td).iterate({"N": 4}))
    # brute force: tiles containing at least one point
    brute = {(i // 2, j // 2) for i in range(4) for j in range(4)
             if 0 <= i <= j <= 3}
    assert tiles == brute


def test_minkowski_box_exact_simple():
    P = Polyhedron.box(("x",), [0], [3])
    S = minkowski_sum_box_exact(P, [F(-1, 2)], [F(0)])
    lo, hi = S.dim_bounds(0)
    assert (lo, hi) == (F(-1, 2), 3)


# --------------------------------------------------- hypothesis properties

coeff = st.integers(-3, 3)
const = st.integers(-4, 4)


@st.composite
def bounded_dep_polyhedron(draw):
    """A bounded 4-dim (2 src + 2 tgt) dependence polyhedron + tilings."""
    rows = []
    n_extra = draw(st.integers(1, 4))
    for _ in range(n_extra):
        r = [draw(coeff) for _ in range(4)] + [draw(const)]
        rows.append(tuple(r))
    box = Polyhedron.box(("a", "b", "c", "d"), [-3] * 4, [3] * 4)
    P = box
    for r in rows:
        P = P.add_ineq(r)
    gs = Tiling((draw(st.integers(1, 3)), draw(st.integers(1, 3))))
    gt = Tiling((draw(st.integers(1, 3)), draw(st.integers(1, 3))))
    return P, gs, gt


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(bounded_dep_polyhedron())
def test_property_compression_equals_projection(data):
    P, gs, gt = data
    a = tile_dependence(P, 2, gs, gt, method="exact")
    b = tile_dependence_projection(P, 2, gs, gt)
    assert a.equals(b)
    infl = tile_dependence(P, 2, gs, gt, method="inflate")
    assert infl.contains(a)
    # integer tile pairs agree between inflation and projection? inflation may
    # add pairs (documented over-approximation); but projection pairs must all
    # be included.
    pa = set(LoopNest(b).iterate(()))
    pi = set(LoopNest(infl).iterate(()))
    assert pa <= pi


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(bounded_dep_polyhedron())
def test_property_scan_count_consistency(data):
    P, _, _ = data
    nest = LoopNest(P)
    pts = list(nest.iterate(()))
    assert len(pts) == nest.count(())
    for p in pts[:20]:
        assert P.contains_point(p)


def test_counting_function_strategies():
    # rectangular -> enumerator
    B = Polyhedron.box(("x", "y"), [0, 0], [4, 5])
    cf = make_counting_function(B, count_dims=[0], fixed_dims=[1])
    assert cf.strategy == "enumerator"
    assert cf((2,), ()) == 5
    # fixing j makes the i-range parametric-rectangular: still an enumerator,
    # and it must evaluate correctly
    cf2 = make_counting_function(tri(), count_dims=[0], fixed_dims=[1])
    assert cf2.strategy == "enumerator"
    assert cf2((3,), (5,)) == 4  # i in 0..3 for j=3
    # a 2-dim triangular count (inner bound depends on outer dim) -> loop
    cf3 = make_counting_function(tri(), count_dims=[0, 1], fixed_dims=[])
    assert cf3.strategy == "loop"
    assert cf3((), (5,)) == 15
