"""Per-arch smoke tests: reduced same-family configs, one train step on CPU,
shape and finiteness assertions, and prefill/decode cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, applicable, input_specs
from repro.models import build_model

ARCHS = sorted(REGISTRY)


def _smoke_model(name):
    cfg = REGISTRY[name].smoke_config().replace(remat=False)
    return cfg, build_model(cfg)


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.arange(B * S).reshape(B, S).astype(jnp.int32) % 17,
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend != "none":
        batch["extra_embeds"] = 0.01 * jnp.ones(
            (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_finite(name):
    cfg, m = _smoke_model(name)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert jnp.isfinite(loss), name
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert jnp.all(jnp.isfinite(g)), (name, path)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes(name):
    cfg, m = _smoke_model(name)
    params = m.init(jax.random.PRNGKey(1), jnp.float32)
    batch = _batch(cfg, B=2, S=12)
    logits, _ = m.forward(params, batch["tokens"],
                          extra_embeds=batch.get("extra_embeds"))
    S_total = 12 + (cfg.frontend_seq if cfg.frontend != "none"
                    and not cfg.encdec else 0)
    assert logits.shape == (2, S_total, cfg.vocab), (name, logits.shape)
    assert jnp.all(jnp.isfinite(logits)), name


DECODE_ARCHS = [a for a in ARCHS if REGISTRY[a].frontend == "none"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_decode_consistency(name):
    """Teacher-forced incremental decode must match the full forward pass."""
    cfg, m = _smoke_model(name)
    params = m.init(jax.random.PRNGKey(2), jnp.float32)
    B, S = 2, 12
    toks = (jnp.arange(B * S).reshape(B, S) % 23).astype(jnp.int32)
    full_logits, _ = m.forward(params, toks)

    caches = m.init_cache(B, 32, jnp.float32)
    k = 6
    _, caches = m.forward(params, toks[:, :k], caches=caches, pos_offset=0)
    outs = []
    for i in range(k, S):
        logits1, caches = m.decode_step(params, toks[:, i:i + 1], caches, i)
        outs.append(logits1)
    inc = jnp.stack(outs, axis=1)                 # [B, S-k, V]
    ref = full_logits[:, k:S]
    np.testing.assert_allclose(np.asarray(inc), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_with_encoder():
    cfg, m = _smoke_model("whisper-tiny")
    from repro.models import encdec
    params = m.init(jax.random.PRNGKey(3), jnp.float32)
    B = 2
    frames = 0.01 * jnp.ones((B, cfg.frontend_seq, cfg.d_model), jnp.float32)
    enc = encdec.encode(cfg, params, frames)
    toks = (jnp.arange(B * 8).reshape(B, 8) % 11).astype(jnp.int32)
    full, _ = encdec.decode(cfg, params, toks, enc)
    caches = m.init_cache(B, 16, jnp.float32)
    outs = []
    _, caches = encdec.decode(cfg, params, toks[:, :4], enc, caches=caches,
                              pos_offset=0)
    for i in range(4, 8):
        l1, caches = m.decode_step(params, toks[:, i:i + 1], caches, i,
                                   enc_out=enc)
        outs.append(l1)
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full[:, 4:8]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache():
    """Windowed decode beyond the window size must keep working (ring)."""
    cfg = REGISTRY["zamba2-7b"].smoke_config().replace(remat=False)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(4), jnp.float32)
    B, S = 1, 40  # window in smoke cfg = 16 << 40
    caches = m.init_cache(B, S + 8, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(24):
        logits, caches = m.decode_step(params, tok, caches, i)
    assert jnp.all(jnp.isfinite(logits))


def test_moe_einsum_routes_all_kept_tokens():
    """MoE output must differ per token (routing) and be finite."""
    cfg, m = _smoke_model("granite-moe-1b-a400m")
    params = m.init(jax.random.PRNGKey(5), jnp.float32)
    toks = (jnp.arange(2 * 16).reshape(2, 16) % 29).astype(jnp.int32)
    logits, _ = m.forward(params, toks)
    assert jnp.all(jnp.isfinite(logits))
    assert float(jnp.std(logits[:, -1])) > 0


def test_deepseek_ep_matches_local_semantics():
    """ep_a2a with ep_size=1 (no axis) must behave like a valid MoE layer."""
    cfg, m = _smoke_model("deepseek-v3-671b")
    params = m.init(jax.random.PRNGKey(6), jnp.float32)
    toks = (jnp.arange(2 * 16).reshape(2, 16) % 13).astype(jnp.int32)
    logits, _ = m.forward(params, toks)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("name,shape", [
    (a, s) for a in ARCHS for s in SHAPES])
def test_input_specs_are_allocation_free(name, shape):
    cfg = REGISTRY[name]
    ok, why = applicable(cfg, shape)
    if not ok:
        assert why
        return
    specs = input_specs(cfg, shape)
    for k, v in specs.items():
        assert isinstance(v, jax.ShapeDtypeStruct), (k, type(v))
        assert all(d >= 1 for d in v.shape)
