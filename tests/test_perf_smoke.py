"""Fast smoke checks of the benchmark entry points (< 1 minute total).

These do not assert absolute timings (CI noise); they assert that every
benchmark section runs end-to-end in smoke mode, emits its CSV rows and
machine-readable payload, and that the taskgen benchmark's built-in
backend-equality checks pass — plus two sanity bounds: compiled must not be
slower than Fraction, and the numpy index-array enumeration must not be
slower than compiled, on a real materialize.
"""
import json
import time

from repro.core.edt import TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS


def _collect(run_fn, **kw):
    lines = []
    out = run_fn(emit=lambda *a, **k: lines.append(str(a[0]) if a else ""), **kw)
    return lines, out


def test_bench_taskgen_smoke():
    from benchmarks import bench_taskgen
    lines, out = _collect(bench_taskgen.run, smoke=True)
    rows = [ln for ln in lines if ln and not ln.startswith("#")]
    # header + one row per (smoke program, backend) + sharded numpy rows
    assert rows[0].startswith("program,backend,shards,")
    per_prog = len(bench_taskgen.BACKENDS) + len(bench_taskgen.SHARD_COUNTS)
    n_expect = len(bench_taskgen.SMOKE_SUITE) * per_prog
    assert len(rows) == 1 + n_expect
    assert any("geomean" in ln for ln in lines)
    # stable machine-readable schema: (name, backend, shards, tasks/sec)
    assert out["schema_version"] == 2
    assert len(out["rows"]) == n_expect
    for r in out["rows"]:
        assert {"program", "backend", "shards", "tasks_per_s"} <= set(r)
        assert r["backend"] in bench_taskgen.BACKENDS
        assert r["shards"] == 1 or r["backend"] == "numpy"
    assert json.dumps(out)  # artifact must be JSON-serializable
    assert out["geomean"]["numpy_enum_over_compiled"] > 0
    # the smoke-scale curve ran, verified byte-identical per shard count
    scale = out["shard_scale"]
    assert [r["shards"] for r in scale] == list(
        bench_taskgen.SCALE_SHARDS) * len(bench_taskgen.SMOKE_SCALE_SUITE)
    assert all(r["n_tasks"] == scale[0]["n_tasks"] for r in scale[:3])


def test_bench_compile_smoke():
    from benchmarks import bench_compile
    lines, _ = _collect(bench_compile.run, smoke=True)
    assert len(lines) == 2 + len(bench_compile.SMOKE_SUITE)
    assert "TIMEOUT" not in "\n".join(lines)


def test_bench_sync_and_executor_smoke():
    from benchmarks import bench_executor, bench_sync_overheads
    sync = bench_sync_overheads.run(emit=lambda *a, **k: None, smoke=True)
    # schema v8: the Table-2 atlas — structured rows with string keys,
    # fitted classes all within the paper's bounds, crossover present
    assert json.dumps(sync)
    assert sync["rows"] and sync["fits"] and sync["growth"]
    assert sync["fit_failures"] == []
    assert len({r["model"] for r in sync["rows"]}) >= 5
    assert len({r["program"] for r in sync["rows"]}) >= 3
    assert {r["path"] for r in sync["crossover"]["rows"]} == {
        "host_sim", "device_replay", "distributed_inline_2"}
    out = bench_executor.run(emit=lambda *a, **k: None, smoke=True)
    assert json.dumps(out)  # v3: executor data must be JSON-serializable
    assert len(out["models"]) == (len(bench_executor.SMOKE_CASES)
                                  * len(bench_executor.MODELS_))
    assert all(r["makespan"] > 0 for r in out["models"])
    # host-vs-device dispatch rows: every path priced and cross-verified
    paths = {r["path"] for r in out["dispatch"]}
    assert {"host", "device_replay", "device_discover"} <= paths
    for r in out["dispatch"]:
        assert {"program", "path", "shards", "tasks", "edges", "depth",
                "seconds", "per_task_us", "verified"} <= set(r)
        assert r["verified"] is True
        assert r["per_task_us"] > 0


def test_run_harness_smoke_mode(tmp_path):
    """`python -m benchmarks.run --smoke --only taskgen --json F` exits
    cleanly and writes the stable artifact schema."""
    from benchmarks import run as harness
    path = tmp_path / "perf.json"
    assert harness.main(["--smoke", "--only", "taskgen",
                         "--json", str(path)]) == 0
    report = json.loads(path.read_text())
    assert report["schema_version"] == 8
    assert report["smoke"] is True
    assert report["host"]["cpus"] >= 1
    sec = report["sections"]["taskgen"]
    assert sec["ok"] is True
    assert sec["data"]["rows"], "taskgen rows missing from artifact"
    assert sec["data"]["shard_scale"], "shard-scale rows missing"
    assert {r["shards"] for r in sec["data"]["rows"]} >= {1, 2}


def test_every_section_round_trips_json_in_smoke():
    """Every section's smoke return value must survive ``json.dumps`` — the
    regression gate for the v2..v7 bug where the ``sync`` section returned
    tuple-keyed dicts and shipped in every artifact as ``repr(...)``."""
    import inspect

    from benchmarks import run as harness
    for name, fn in harness.section_registry().items():
        params = inspect.signature(fn).parameters
        kw = {}
        if "smoke" in params:
            kw["smoke"] = True
        if "emit" in params:
            kw["emit"] = lambda *a, **k: None
        ok, data = harness.encode_section_data(fn(**kw))
        assert ok, f"section {name} returned unserializable data: {data}"


def test_encode_section_data_fails_loudly():
    """Unserializable section data is an error record, never a repr."""
    from benchmarks.run import encode_section_data
    ok, data = encode_section_data({("model", 4): 1})   # the old sync shape
    assert ok is False
    assert "unserializable" in data and data["type"] == "dict"
    ok, data = encode_section_data({"rows": [1, 2]})
    assert ok is True and data == {"rows": [1, 2]}


def test_service_section_smoke():
    """The schema-v5 graph-cache section: warm hits verified against the
    cold products, flagship row present, service stats coalesce
    (docs/service.md)."""
    from benchmarks import bench_service
    lines, out = _collect(bench_service.run, smoke=True)
    assert any(ln.startswith("case,kind,") for ln in lines)
    assert out["rows"], "service rows missing"
    for r in out["rows"]:
        assert {"case", "kind", "n_tasks", "cold_ms", "warm_ms", "speedup",
                "sub_ms_warm", "verified"} <= set(r)
        assert r["verified"] is True
        assert r["sub_ms_warm"] is True
        assert r["speedup"] > 1
    flag = out["flagship"]
    assert flag["kind"] == "packed" and flag["verified"] is True
    svc = out["service"]
    assert svc["cold_fills"] == svc["keys"]      # exactly-once per key
    assert svc["hit_rate"] > 0.5                 # everything else was warm
    assert json.dumps(out)


def test_fused_section_smoke():
    """The schema-v6 fused-execution section: every path priced per task
    and per point, numerics verified against the handwritten solve
    (docs/device_exec.md, "Fused execution")."""
    from benchmarks import bench_fused
    lines, out = _collect(bench_fused.run, smoke=True)
    assert any(ln.startswith("program,path,") for ln in lines)
    assert out["rows"], "fused rows missing"
    paths = {r["path"] for r in out["rows"]}
    assert {"handwritten", "device_replay", "fused", "fused_novalidate",
            "host_dispatch"} <= paths
    for r in out["rows"]:
        assert {"program", "path", "tasks", "points", "seconds",
                "per_task_us", "per_point_ns", "vs_handwritten",
                "verified"} <= set(r)
        assert r["verified"] is True
    # the acceptance record only exists on the full flagship run
    assert out["acceptance"] is None
    assert json.dumps(out)


def test_distributed_section_smoke():
    """The schema-v7 distributed section: every (ranks, transport) row
    byte-verified against the single-host oracle, message volume equal to
    the cross-partition edge count (docs/distributed.md)."""
    from benchmarks import bench_distributed
    lines, out = _collect(bench_distributed.run, smoke=True)
    assert any(ln.startswith("ranks,transport,") for ln in lines)
    assert out["rows"], "distributed rows missing"
    for r in out["rows"]:
        assert {"program", "tasks", "ranks", "engine", "transport",
                "seconds", "per_task_us", "msgs", "batches", "cross_frac",
                "attempts", "per_rank", "verified"} <= set(r)
        assert r["verified"] is True
        assert len(r["per_rank"]) == r["ranks"]
        assert sum(s["n_local"] for s in r["per_rank"]) == r["tasks"]
    one = next(r for r in out["rows"] if r["ranks"] == 1)
    assert one["msgs"] == 0                      # no cross edges at 1 rank
    assert json.dumps(out)


def test_faults_section_smoke():
    """The recovery-overhead section: rows verified, faults
    actually fired, artifact JSON-serializable (docs/robustness.md)."""
    from benchmarks import bench_faults
    lines, out = _collect(bench_faults.run, smoke=True)
    assert any(ln.startswith("shards,fault,") for ln in lines)
    assert out["rows"], "faults rows missing"
    for r in out["rows"]:
        assert {"shards", "fault", "clean_s", "faulty_s",
                "overhead_ratio", "verified"} <= set(r)
        assert r["verified"] is True
    assert json.dumps(out)


def test_compiled_not_slower_than_fraction():
    """Loose perf floor: the whole point of the backend, cheaply verified."""
    tilings = {"S": Tiling((2, 2, 2))}
    params = {"T": 6, "N": 10}
    gc = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings)
    gf = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings, backend="fraction")
    t0 = time.perf_counter()
    mc = gc.materialize(params)
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    mf = gf.materialize(params)
    t_f = time.perf_counter() - t0
    assert mc.succ == mf.succ
    assert t_c < t_f  # compiled wins by ~50x; < is a generous CI-safe bound


def test_numpy_enum_not_slower_than_compiled():
    """The vectorized index-array enumeration must beat the scalar compiled
    materialize (it wins by ~5-10x; < is a generous CI-safe bound)."""
    tilings = {"S": Tiling((1, 1))}
    params = {"K": 40}
    gc = TiledTaskGraph(PROGRAMS["diamond"](), tilings)
    gn = TiledTaskGraph(PROGRAMS["diamond"](), tilings, backend="numpy")
    gc.materialize(params)          # warm both codegens outside the timing
    gn.index_graph(params)
    t0 = time.perf_counter()
    mc = gc.materialize(params)
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    ig = gn.index_graph(params)
    t_n = time.perf_counter() - t0
    assert ig.n == len(mc.tasks) and ig.n_edges == mc.n_edges
    assert t_n < t_c
