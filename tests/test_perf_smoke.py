"""Fast smoke checks of the benchmark entry points (< 1 minute total).

These do not assert absolute timings (CI noise); they assert that every
benchmark section runs end-to-end in smoke mode, emits its CSV rows, and
that the taskgen benchmark's built-in backend-equality checks pass — plus
one sanity bound: the compiled backend must not be slower than the Fraction
reference on a real materialize.
"""
import time

from repro.core.edt import TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS


def _collect(run_fn, **kw):
    lines = []
    run_fn(emit=lambda *a, **k: lines.append(str(a[0]) if a else ""), **kw)
    return lines


def test_bench_taskgen_smoke():
    from benchmarks import bench_taskgen
    lines = _collect(bench_taskgen.run, smoke=True)
    # header + one row per smoke program + geomean line
    assert len(lines) == 2 + len(bench_taskgen.SMOKE_SUITE)
    assert lines[0].startswith("program,")
    assert "geomean" in lines[-1]


def test_bench_compile_smoke():
    from benchmarks import bench_compile
    lines = _collect(bench_compile.run, smoke=True)
    assert len(lines) == 2 + len(bench_compile.SMOKE_SUITE)
    assert "TIMEOUT" not in "\n".join(lines)


def test_bench_sync_and_executor_smoke():
    from benchmarks import bench_executor, bench_sync_overheads
    rows = bench_sync_overheads.run(emit=lambda *a, **k: None, smoke=True)
    assert rows  # one entry per (model, size)
    out = bench_executor.run(emit=lambda *a, **k: None, smoke=True)
    assert all(v > 0 for v in out.values())


def test_run_harness_smoke_mode():
    """`python -m benchmarks.run --smoke --only taskgen` exits cleanly."""
    from benchmarks import run as harness
    assert harness.main(["--smoke", "--only", "taskgen"]) == 0


def test_compiled_not_slower_than_fraction():
    """Loose perf floor: the whole point of the backend, cheaply verified."""
    tilings = {"S": Tiling((2, 2, 2))}
    params = {"T": 6, "N": 10}
    gc = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings)
    gf = TiledTaskGraph(PROGRAMS["jacobi2d"](), tilings, backend="fraction")
    t0 = time.perf_counter()
    mc = gc.materialize(params)
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    mf = gf.materialize(params)
    t_f = time.perf_counter() - t0
    assert mc.succ == mf.succ
    assert t_c < t_f  # compiled wins by ~50x; < is a generous CI-safe bound
