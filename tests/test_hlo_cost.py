"""Calibration tests for the trip-count-aware HLO cost analyzer.

These pin the §Roofline methodology: XLA's cost_analysis counts while-loop
bodies once; analyze_hlo must recover the true totals.
"""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_plain_matmul_flops_exact():
    N = 512
    comp = _compile(lambda a, b: a @ b,
                    jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((N, N), jnp.float32))
    a = analyze_hlo(comp.as_text())
    assert a["flops"] == 2 * N ** 3


def test_scan_multiplies_trip_count():
    N, L = 256, 8
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=L)[0]
    comp = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32))
    a = analyze_hlo(comp.as_text())
    assert a["flops"] == L * 2 * N ** 3
    # and the raw XLA analysis indeed under-counts (the reason this exists)
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca.get("flops", 0) <= 2 * N ** 3 + 1e6


def test_nested_scans_multiply():
    N, L1, L2 = 128, 3, 5
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            return jax.lax.scan(inner, c, None, length=L2)[0], None
        return jax.lax.scan(outer, x, None, length=L1)[0]
    comp = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32))
    a = analyze_hlo(comp.as_text())
    assert a["flops"] == L1 * L2 * 2 * N ** 3


def test_einsum_contraction_flops():
    B, M, K, Nn = 4, 64, 96, 32
    def f(a, b):
        return jnp.einsum("bmk,kn->bmn", a, b)
    comp = _compile(f, jax.ShapeDtypeStruct((B, M, K), jnp.float32),
                    jax.ShapeDtypeStruct((K, Nn), jnp.float32))
    a = analyze_hlo(comp.as_text())
    assert a["flops"] == 2 * B * M * K * Nn
