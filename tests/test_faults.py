"""Fault-injection matrix: every failure mode × every execution engine.

The robustness contract (``docs/robustness.md``) is differential: under a
seeded :class:`FaultPlan`, a *recoverable* run must end byte-identical to
the fault-free oracle, and an *unrecoverable* run must end in a structured
report (``ShardRecoveryError`` / ``TaskGroupError`` / ``StallError``) —
never a hang, never a leaked ``/dev/shm`` segment.

The matrix crosses {worker crash round 0/1/2 (soft and hard), hung worker,
shm attach failure, task-body exception, dropped decrement} with {sharded
materialization, threaded autodec, instrumented Sim, device discover}.  A
seeded fuzz loop (hypothesis when available, deterministic otherwise)
drives random plans through random polyhedral programs asserting the same
byte-identical-or-reported property.

When ``FAULT_ARTIFACT_DIR`` is set (the CI fault-injection job), every
structured report produced here is also written out as JSON.
"""
from __future__ import annotations

import gc
import json
import os
import random
from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest
from test_backend_differential import _build_program

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:
    from hypo_stub import HealthCheck, given, settings, st

from repro.core.edt import (DROPPED_DECREMENT, SHM_ATTACH_FAIL,
                            ExecutionConfig,
                            TASK_BODY_ERROR, WORKER_CRASH, WORKER_HANG,
                            Fault, FaultPlan, RetryPolicy,
                            ShardRecoveryError, Sim, StallError,
                            TaskGroupError, TiledTaskGraph, DeviceExecutor,
                            poisoned_cone, run_graph_threaded,
                            run_graph_threaded_resilient, simulate_indexed,
                            simulate_indexed_resilient, synthesize_indexed)
from repro.core.edt.shard import _Segments
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

FAST_RETRY = RetryPolicy(max_retries=2, base_delay=0.001, timeout=5.0)


def _artifact(name: str, payload: str) -> None:
    d = os.environ.get("FAULT_ARTIFACT_DIR")
    if not d:
        return
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, name + ".json"), "w") as f:
        f.write(payload)


def _shm_listing() -> set:
    try:
        return set(os.listdir("/dev/shm"))
    except OSError:          # non-POSIX-shm platform: leak check is vacuous
        return set()


@pytest.fixture()
def shm_guard():
    """Assert the test leaked no /dev/shm segments (crashes included)."""
    before = _shm_listing()
    yield
    gc.collect()
    leaked = _shm_listing() - before
    assert not leaked, f"leaked shm segments: {sorted(leaked)}"


def _graph_and_oracle():
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((2, 2))},
                      backend="numpy")
    params = {"N": 21}
    return g, params, g.index_graph(params)


def _assert_identical(ig, oracle):
    assert ig.n == oracle.n
    assert np.array_equal(ig.edge_src, oracle.edge_src)
    assert np.array_equal(ig.edge_tgt, oracle.edge_tgt)
    assert np.array_equal(ig.pred_n, oracle.pred_n)
    assert len(ig.stmt_blocks) == len(oracle.stmt_blocks)
    for (sa, ba), (sb, bb) in zip(ig.stmt_blocks, oracle.stmt_blocks):
        assert sa == sb and np.array_equal(ba, bb)


# ===================================================== sharded recovery
SHARD_MATRIX = [
    Fault(kind=WORKER_CRASH, round=0, index=0, times=1),
    Fault(kind=WORKER_CRASH, round=1, index=1, times=2),
    Fault(kind=WORKER_CRASH, round=2, index=0, times=1),
    Fault(kind=WORKER_CRASH, round=1, index=0, times=1, hard=True),
    Fault(kind=WORKER_HANG, round=1, index=0, times=1, delay=2.0),
    Fault(kind=SHM_ATTACH_FAIL, round=2, index=1, times=2),
]


@pytest.mark.parametrize("fault", SHARD_MATRIX,
                         ids=lambda f: f"{f.kind}-r{f.round}-x{f.times}"
                         + ("-hard" if f.hard else ""))
def test_sharded_recoverable_is_byte_identical(fault, shm_guard):
    """Faults within the retry budget: re-materialized shards must land
    byte-identical to the fault-free single-process oracle."""
    g, params, oracle = _graph_and_oracle()
    plan = FaultPlan(faults=(fault,))
    policy = FAST_RETRY if fault.kind != WORKER_HANG else RetryPolicy(
        max_retries=3, base_delay=0.001, timeout=0.6)
    ig = g.index_graph(
        params, config=ExecutionConfig(shards=2, faults=plan, recovery=policy))
    _assert_identical(ig, oracle)
    assert plan.fired, "the fault never actually fired"


def test_sharded_unrecoverable_reports(shm_guard):
    """A fault outliving the retry budget must surface a ShardRecoveryError
    carrying the structured report — and still release every segment."""
    g, params, _ = _graph_and_oracle()
    plan = FaultPlan(faults=(Fault(kind=WORKER_CRASH, round=2, index=1,
                                   times=99),))
    with pytest.raises(ShardRecoveryError) as ei:
        g.index_graph(params, config=ExecutionConfig(
            shards=2, faults=plan, recovery=FAST_RETRY))
    rep = ei.value.report
    assert rep.context == "sharded"
    assert rep.failed and rep.failed[0][0] == (2, 1)
    assert rep.attempts[(2, 1)] == FAST_RETRY.max_retries + 1
    _artifact("sharded_unrecoverable", rep.to_json())


def test_sharded_hard_crash_in_caller_pool_is_unrecoverable(shm_guard):
    """A hard crash breaks the pool; scan_sharded must not rebuild a pool
    it does not own — that is the caller's resource."""
    g, params, _ = _graph_and_oracle()
    plan = FaultPlan(faults=(Fault(kind=WORKER_CRASH, round=0, index=0,
                                   times=1, hard=True),))
    pool = ProcessPoolExecutor(max_workers=2)
    try:
        with pytest.raises(ShardRecoveryError):
            g.index_graph(params, config=ExecutionConfig(
                shards=2, pool=pool, faults=plan, recovery=FAST_RETRY))
    finally:
        pool.shutdown(wait=False)


def test_sharded_faults_without_policy_use_default_retry(shm_guard):
    """faults= without recovery= falls back to the default RetryPolicy —
    injection alone never silently disables recovery."""
    g, params, oracle = _graph_and_oracle()
    plan = FaultPlan(faults=(Fault(kind=WORKER_CRASH, round=1, index=0),))
    ig = g.index_graph(params,
                       config=ExecutionConfig(shards=2, faults=plan))
    _assert_identical(ig, oracle)
    assert plan.fired


def test_sharded_zero_retry_budget_fails_fast(shm_guard):
    """max_retries=0 is structured fail-fast: first failure → report."""
    g, params, _ = _graph_and_oracle()
    plan = FaultPlan(faults=(Fault(kind=WORKER_CRASH, round=1, index=0),))
    with pytest.raises(ShardRecoveryError) as ei:
        g.index_graph(params, config=ExecutionConfig(
            shards=2, faults=plan,
            recovery=RetryPolicy(max_retries=0, base_delay=0.001)))
    assert "injected worker crash" in ei.value.report.failed[0][1]


def test_segments_finalizer_sweeps_on_collection():
    """Satellite 1: dropping a _Segments without release() must still
    unlink its /dev/shm files (weakref.finalize, also runs atexit)."""
    before = _shm_listing()
    segs = _Segments(enabled=True)
    if not segs.allocate(("S", 0), (8,)):
        pytest.skip("shared memory unavailable on this platform")
    created = _shm_listing() - before
    assert created, "allocation produced no segment"
    del segs
    gc.collect()
    assert not (_shm_listing() - before), "finalizer did not unlink"


# ===================================================== threaded autodec
def test_threaded_aggregates_every_failure():
    """Satellite 2: every (task, exception) pair rides one TaskGroupError."""
    g, params, _ = _graph_and_oracle()
    tasks = list(g.tasks(params))
    _, sched = synthesize_indexed(g, params)
    wide = next(lv for lv in sched.levels if len(lv) >= 2)
    victims = [tasks[int(i)] for i in wide[:2]]
    plan = FaultPlan(faults=tuple(
        Fault(kind=TASK_BODY_ERROR, task=t) for t in victims))
    with pytest.raises(TaskGroupError) as ei:
        run_graph_threaded(g, params, workers=4, faults=plan)
    failed_keys = {k for k, _ in ei.value.failures}
    assert failed_keys == set(victims)
    rep = ei.value.report
    assert rep.context == "threaded" and len(rep.failed) == 2
    _artifact("threaded_taskgroup", rep.to_json())


def test_threaded_quarantine_matches_cone_oracle():
    """Resilient mode cancels exactly the dependent cone of the failure."""
    g, params, _ = _graph_and_oracle()
    tasks = list(g.tasks(params))
    victim = tasks[len(tasks) // 3]
    plan = FaultPlan(faults=(Fault(kind=TASK_BODY_ERROR, task=victim),))
    res = run_graph_threaded_resilient(g, params, workers=4, faults=plan)
    assert not res.ok and res.stall is None
    rep = res.failure
    # closure oracle recomputed independently of the runtime
    cone, frontier = set(), [victim]
    while frontier:
        nxt = []
        for t in frontier:
            for s in g.successors(t, params):
                if s != victim and s not in cone:
                    cone.add(s)
                    nxt.append(s)
        frontier = nxt
    assert set(rep.poisoned) == cone
    assert set(res.executed) == set(tasks) - cone - {victim}
    assert all(t in cone for t in rep.undrained)


def test_threaded_hang_becomes_stall_report():
    g, params, _ = _graph_and_oracle()
    victim = list(g.roots(params))[0]
    plan = FaultPlan(faults=(Fault(kind=WORKER_HANG, task=victim,
                                   delay=3.0),))
    with pytest.raises(StallError) as ei:
        run_graph_threaded(g, params, workers=2, faults=plan,
                           stall_timeout=0.4)
    rep = ei.value.report
    assert rep.context == "threaded"
    assert rep.in_flight >= 1           # the hung body never finished
    _artifact("threaded_stall_hang", rep.to_json())


def test_threaded_dropped_decrement_is_diagnosed():
    """A swallowed signal must not look like success: the runtime quiesces
    incomplete and the stall report names the starved counters."""
    g, params, _ = _graph_and_oracle()
    tasks = list(g.tasks(params))
    victim = tasks[len(tasks) // 2]
    plan = FaultPlan(faults=(Fault(kind=DROPPED_DECREMENT, task=victim),))
    res = run_graph_threaded_resilient(g, params, workers=4, faults=plan,
                                       stall_timeout=5.0)
    assert res.stall is not None
    assert "decrement was dropped" in res.stall.note
    assert victim in res.stall.undrained
    assert victim not in res.executed
    _artifact("threaded_stall_dropped", res.stall.to_json())


def test_threaded_clean_run_unchanged_under_fault_machinery():
    g, params, _ = _graph_and_oracle()
    plain = run_graph_threaded(g, params, workers=4)
    res = run_graph_threaded_resilient(g, params, workers=4,
                                       faults=FaultPlan())
    assert res.ok
    assert set(res.executed) == set(plain)


# ============================================================ sim engine
def test_sim_resilient_clean_is_byte_identical():
    g, params, _ = _graph_and_oracle()
    ig, sched = synthesize_indexed(g, params)
    res = simulate_indexed_resilient(ig, sched)
    ref = simulate_indexed(sched)
    assert res.ok
    assert res.sim.exec_order == ref.exec_order
    assert res.sim.now == ref.now


def test_sim_quarantine_matches_vectorized_cone():
    g, params, _ = _graph_and_oracle()
    ig, sched = synthesize_indexed(g, params)
    victim = int(sched.levels[1][0])
    plan = FaultPlan(faults=(Fault(kind=TASK_BODY_ERROR, task=victim),))
    res = simulate_indexed_resilient(ig, sched, faults=plan)
    assert not res.ok
    rep = res.report
    cone = poisoned_cone(ig.n, ig.edge_src, ig.edge_tgt, [victim])
    assert rep.poisoned == sorted(int(t) for t in cone)
    # the victim was dispatched (its body raised), so it is in exec_order;
    # everything outside its cone ran, nothing inside it was dispatched
    executed = set(res.sim.exec_order)
    assert executed == set(range(ig.n)) - set(cone)
    assert rep.executed + len(rep.poisoned) == ig.n
    _artifact("sim_quarantine", rep.to_json())


def test_sim_on_task_error_hook():
    """The raw Sim hook: a failing run_fn is recorded, the slot is freed,
    and the event loop keeps dispatching instead of unwinding."""
    seen = []
    sim = Sim(workers=1, on_task_error=lambda t, e: seen.append((t, e)))

    def boom():
        raise ValueError("body failed")

    sim.make_ready("bad", boom)
    sim.make_ready("good", lambda: None)
    sim.run()
    assert [t for t, _ in seen] == ["bad"]
    assert [t for t, _ in sim.task_errors] == ["bad"]
    assert "good" in sim.exec_order and "bad" in sim.exec_order


def test_sim_without_hook_still_raises():
    sim = Sim(workers=1)

    def boom():
        raise ValueError("body failed")

    sim.make_ready("bad", boom)
    with pytest.raises(ValueError, match="body failed"):
        sim.run()


# ========================================================== device layer
def test_device_discover_dropped_decrement_stalls_with_report():
    g, params, _ = _graph_and_oracle()
    ig, sched = synthesize_indexed(g, params)
    victim = int(sched.levels[1][0])
    plan = FaultPlan(faults=(Fault(kind=DROPPED_DECREMENT, task=victim),))
    with pytest.raises(StallError) as ei:
        DeviceExecutor(ig, config=ExecutionConfig(faults=plan)).run()
    rep = ei.value.report
    assert rep.context == "device-discover"
    assert victim in rep.undrained
    assert plan.fired
    _artifact("device_stall_dropped", rep.to_json())


def test_device_discover_clean_run_ignores_empty_plan():
    g, params, _ = _graph_and_oracle()
    ig, sched = synthesize_indexed(g, params)
    clean = DeviceExecutor(ig).run()
    fp = DeviceExecutor(ig, config=ExecutionConfig(faults=FaultPlan())).run()
    assert [np.asarray(a).tolist() for a in fp.levels] == \
           [np.asarray(a).tolist() for a in clean.levels]


# ============================================================== fuzzing
def _fuzz_one(seed: int) -> None:
    rng = random.Random(seed)
    prog, tilings, params = _build_program(rng)
    g = TiledTaskGraph(prog, tilings, backend="numpy")
    oracle = g.index_graph(params)
    plan = FaultPlan.random(seed, n_jobs=2,
                            kinds=(WORKER_CRASH, SHM_ATTACH_FAIL))
    try:
        ig = g.index_graph(params, config=ExecutionConfig(
            shards=2, faults=plan, recovery=FAST_RETRY))
    except ShardRecoveryError as e:
        assert not plan.recoverable(FAST_RETRY.max_retries)
        assert e.report.failed
        _artifact(f"fuzz_seed{seed}", e.report.to_json())
    else:
        assert plan.recoverable(FAST_RETRY.max_retries)
        _assert_identical(ig, oracle)


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_random_fault_plans(seed, shm_guard):
    """Byte-identical-or-reported over random plans × random programs."""
    _fuzz_one(seed)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(min_value=0, max_value=10_000))
def test_fuzz_random_fault_plans_hypothesis(seed):
    _fuzz_one(seed)


def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(1234, n_jobs=4)
    b = FaultPlan.random(1234, n_jobs=4)
    assert a.faults == b.faults


def test_fault_plan_report_roundtrip():
    """Report JSON must be loadable — the CI artifact contract."""
    g, params, _ = _graph_and_oracle()
    victim = list(g.tasks(params))[5]
    plan = FaultPlan(faults=(Fault(kind=TASK_BODY_ERROR, task=victim),))
    res = run_graph_threaded_resilient(g, params, workers=2, faults=plan)
    doc = json.loads(res.failure.to_json())
    assert doc["context"] == "threaded"
    assert doc["n_failed"] == 1
