"""Cross-backend differential harness: every generation path, one graph.

Randomly generated small polyhedral programs (seeded — deterministic in CI)
are materialized through every path the repo has:

* the ``fraction`` reference backend (exact rational oracle),
* the ``compiled`` integer-codegen backend,
* the ``numpy`` vectorized batch backend (dict view and ``index_graph``),
* the sharded process-pool engine (``shards=n``, shm and pickle
  transports),

and every product — task list, adjacency, §4.3 predecessor counts, root
set, flat edge columns — must be identical.  The same property is exposed
through hypothesis when it is installed (via the ``hypo_stub`` shim it
skips cleanly otherwise); the seeded loop below keeps the differential
coverage running either way.
"""
from __future__ import annotations

import random
from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; deterministic tests still run
    from hypo_stub import HealthCheck, given, settings, st

from repro.core.edt import ExecutionConfig, PolyhedralProgram, TiledTaskGraph
from repro.core.edt.shard import plan_shards, scan_sharded
from repro.core.poly import Polyhedron, Tiling
from repro.core.programs import PROGRAMS, dep

BACKENDS = ("fraction", "compiled", "numpy")


@pytest.fixture(scope="module")
def pool():
    p = ProcessPoolExecutor(max_workers=2)
    p.submit(int, 0).result()   # absorb spawn cost
    yield p
    p.shutdown()


# ------------------------------------------------------------- generator
def _random_domain(rng: random.Random, nd: int):
    """Box 0 <= x_i < E_i (E_0 may be the parameter N), optionally made
    triangular with x_1 <= x_0 — the §4.3 counting-loop shape."""
    param_extent = rng.random() < 0.5
    extents = [rng.randint(2, 5) for _ in range(nd)]
    rows = []
    for i in range(nd):
        lo = [0] * (nd + 2)
        lo[i] = 1
        hi = [0] * (nd + 2)
        hi[i] = -1
        if i == 0 and param_extent:
            hi[nd] = 1      # x_0 <= N - 1
            hi[-1] = -1
        else:
            hi[-1] = extents[i] - 1
        rows += [lo, hi]
    triangular = nd >= 2 and rng.random() < 0.4
    if triangular:
        r = [0] * (nd + 2)
        r[0], r[1] = 1, -1          # x_1 <= x_0
        rows.append(r)
    return Polyhedron.from_ineqs(
        tuple(f"x{i}" for i in range(nd)), ("N",), rows)


def _random_dep_rows(rng: random.Random, nd: int):
    """(eqs, ineqs) over [src, tgt, N, 1] — lex-positive, so the graph is
    acyclic and the root set is nontrivial."""
    if rng.random() < 0.7:
        # uniform shift with lex-positive distance
        while True:
            off = [rng.choice([-1, 0, 0, 1, 1, 2]) for _ in range(nd)]
            nz = [o for o in off if o]
            if nz and next(o for o in off if o) > 0:
                break
        eqs = []
        for i in range(nd):
            e = [0] * (2 * nd + 2)
            e[i], e[nd + i], e[-1] = 1, -1, off[i]   # x_t_i = x_s_i + off_i
            eqs.append(e)
        return eqs, []
    # non-uniform: advance dim 0, fan out over dim 1 (x_t_1 >= x_s_1)
    eqs = []
    e = [0] * (2 * nd + 2)
    e[0], e[nd], e[-1] = 1, -1, 1
    eqs.append(e)
    for i in range(2, nd):
        e = [0] * (2 * nd + 2)
        e[i], e[nd + i] = 1, -1
        eqs.append(e)
    ineqs = []
    if nd >= 2:
        r = [0] * (2 * nd + 2)
        r[1], r[nd + 1] = -1, 1                      # x_t_1 >= x_s_1
        ineqs.append(r)
        r = [0] * (2 * nd + 2)
        r[1], r[nd + 1], r[-1] = 1, -1, 2            # x_t_1 <= x_s_1 + 2
        ineqs.append(r)
    return eqs, ineqs


def _build_program(rng: random.Random):
    nd = rng.choice([1, 2, 2, 3])
    P = PolyhedralProgram()
    D = _random_domain(rng, nd)
    P.add_statement("S", D)
    for j in range(rng.randint(1, 2)):
        eqs, ineqs = _random_dep_rows(rng, nd)
        P.add_dependence("S", "S", dep(D, D, eqs=eqs, ineqs=ineqs),
                         f"d{j}")
    tiling = Tiling(tuple(rng.randint(1, 3) for _ in range(nd)))
    params = {"N": rng.randint(4, 9)}
    return P, {"S": tiling}, params


# ------------------------------------------------------------ comparator
def assert_paths_identical(prog, tilings, params, pool=None,
                           shard_counts=(3,), use_shm=True):
    """The differential property: every generation path, identical graph."""
    graphs = {b: TiledTaskGraph(prog, tilings, backend=b) for b in BACKENDS}
    ref = graphs["fraction"].materialize(params)
    ref_roots = list(graphs["fraction"].roots(params))
    ref_counts = [graphs["fraction"].pred_count(t, params) for t in ref.tasks]
    for b in ("compiled", "numpy"):
        m = graphs[b].materialize(params)
        assert m.tasks == ref.tasks, b
        assert m.succ == ref.succ, b
        assert m.pred_n == ref.pred_n, b
        assert list(graphs[b].roots(params)) == ref_roots, b
        assert [graphs[b].pred_count(t, params) for t in ref.tasks] == ref_counts, b
    g = graphs["numpy"]
    ig = g.index_graph(params)
    assert ig.tasks == ref.tasks
    assert ig.pred_n.tolist() == [ref.pred_n[t] for t in ref.tasks]
    edges = sorted((ig.tasks[s], ig.tasks[t])
                   for s, t in zip(ig.edge_src.tolist(),
                                   ig.edge_tgt.tolist()))
    assert edges == sorted((u, v) for u, ss in ref.succ.items() for v in ss)
    for s in shard_counts:
        cfg = ExecutionConfig(shards=s, pool=pool)
        for gb in (g, graphs["compiled"]):
            m = gb.materialize(params, config=cfg)
            assert m.tasks == ref.tasks, f"sharded tasks differ (x{s})"
            assert m.succ == ref.succ, f"sharded adjacency differs (x{s})"
            assert m.pred_n == ref.pred_n, f"sharded counts differ (x{s})"
        igs = g.index_graph(params, config=cfg)
        assert np.array_equal(igs.edge_src, ig.edge_src)
        assert np.array_equal(igs.edge_tgt, ig.edge_tgt)
        assert np.array_equal(igs.pred_n, ig.pred_n)
        for (na, xa), (nb, xb) in zip(igs.stmt_blocks, ig.stmt_blocks):
            assert na == nb and np.array_equal(xa, xb)
        assert list(g.roots(params, config=cfg)) == ref_roots
        if not use_shm:
            scans = scan_sharded(g, params, s, pool=pool, use_shm=False)
            m = g._materialize_numpy(g._pv(params), scans=scans)
            assert m.succ == ref.succ and m.pred_n == ref.pred_n


# ------------------------------------------------------- deterministic
def test_differential_random_programs(pool):
    """Seeded sweep: 12 random programs through every path."""
    rng = random.Random(20260731)
    for case in range(12):
        prog, tilings, params = _build_program(rng)
        assert_paths_identical(prog, tilings, params, pool=pool)


def test_differential_pickle_transport(pool):
    """The no-shared-memory fallback produces the same graphs."""
    rng = random.Random(7)
    for case in range(3):
        prog, tilings, params = _build_program(rng)
        assert_paths_identical(prog, tilings, params, pool=pool,
                               shard_counts=(2,), use_shm=False)


def test_differential_named_programs(pool):
    """The paper-suite shapes (triangular, multi-dep, stencil) as anchors."""
    cases = [
        ("trisolv", (2, 2), {"N": 21}),
        ("seidel1d", (3, 3), {"T": 9, "N": 21}),
        ("diamond", (1, 1), {"K": 9}),
    ]
    for name, tiles, params in cases:
        assert_paths_identical(PROGRAMS[name](), {"S": Tiling(tiles)},
                               params, pool=pool, shard_counts=(2, 5))


def test_plan_is_deterministic_and_partitions():
    """Shard plans depend only on (graph, params, shards): stable block
    boundaries that exactly partition each unit's outer extent."""
    g = TiledTaskGraph(PROGRAMS["trisolv"](), {"S": Tiling((2, 2))})
    params = {"N": 33}
    p1 = plan_shards(g, params, 4)
    p2 = plan_shards(g, params, 4)
    assert p1.tile_specs == p2.tile_specs
    assert p1.edge_specs == p2.edge_specs
    by_unit = {}
    for s in p1.tile_specs + p1.edge_specs:
        by_unit.setdefault((s.kind, s.key), []).append(s)
    for specs in by_unit.values():
        specs.sort(key=lambda s: s.seq)
        for a, b in zip(specs, specs[1:]):
            assert b.lo == a.hi + 1, "blocks must tile the outer range"
        assert all(s.lo <= s.hi for s in specs)


def test_sharded_restricted_scan_is_slice():
    """A __slo/__shi-restricted scan equals the matching slice of the full
    scan — the invariant the whole merge rests on."""
    from repro.core.poly import LoopNest, shard_polyhedron
    g = TiledTaskGraph(PROGRAMS["lu_like"](), {"S": Tiling((2, 2, 2))})
    params = {"N": 11}
    pv = g._pv(params)
    for nest in list(g.tile_nests.values()) + [g._joint_nest(td) for td in g.tiled_deps]:
        full = nest.iterate_array(pv)
        lb, ub = nest.outer_bounds(pv)
        if full.shape[0]:
            assert lb == int(full[:, 0].min())
            assert ub == int(full[:, 0].max())
        snest = LoopNest(shard_polyhedron(nest.poly))
        mid = (lb + ub) // 2
        for lo, hi in ((lb, mid), (mid + 1, ub), (lb, ub), (ub + 1, ub + 3)):
            block = snest.iterate_array(pv + [lo, hi])
            mask = (full[:, 0] >= lo) & (full[:, 0] <= hi)
            assert np.array_equal(block, full[mask])


def test_sharded_counts_match_scans():
    """The counting round's exact pre-counts equal what the scans produce —
    asserted in-process here (workers re-assert it on every deposit)."""
    from repro.core.edt.shard import (_block_scan, _count_shard, _CountJob,
                                      _diag_shard_poly)
    g = TiledTaskGraph(PROGRAMS["seidel1d"](), {"S": Tiling((3, 3))})
    params = {"T": 12, "N": 30}
    plan = plan_shards(g, params, 3)
    for spec in plan.tile_specs:
        n = _count_shard(_CountJob(spec, None))
        assert _block_scan(spec).shape[0] == n
    for spec in plan.edge_specs:
        td = g.tiled_deps[spec.key]
        diag = (_diag_shard_poly(g, spec.key)
                if td.dep.src == td.dep.tgt else None)
        n = _count_shard(_CountJob(spec, diag))
        arr = _block_scan(spec)
        if td.dep.src == td.dep.tgt and arr.shape[0]:
            ns = g.tilings[td.dep.src].ndim
            arr = arr[(arr[:, :ns] != arr[:, ns:]).any(axis=1)]
        assert arr.shape[0] == n


# --------------------------------------------------------- hypothesis
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(min_value=0, max_value=10_000))
def test_differential_property(seed):
    """Hypothesis twin of the seeded sweep (skips without hypothesis)."""
    rng = random.Random(seed)
    prog, tilings, params = _build_program(rng)
    assert_paths_identical(prog, tilings, params, pool=None,
                           shard_counts=(2,))
