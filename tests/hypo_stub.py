"""Fallback shim for ``hypothesis`` so property tests skip cleanly.

The container does not ship hypothesis and nothing may be pip-installed.
Test modules import via::

    try:
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st
    except ImportError:
        from hypo_stub import HealthCheck, given, settings, st

When the real library is absent, ``@given`` replaces the test with a
zero-argument function that calls ``pytest.skip`` — the deterministic tests
in the same module keep running, and the property tests show up as skipped
instead of breaking collection.
"""
from __future__ import annotations

import pytest


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


def settings(*_a, **_k):
    def deco(fn):
        return fn
    return deco


def given(*_a, **_k):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


class _Strategies:
    """Any strategy constructor returns an inert placeholder.

    Strategy expressions are evaluated at decoration time (e.g.
    ``@given(st.integers(0, 3))``), so they only need to not raise.
    ``st.composite`` bodies are never executed because ``@given`` skips.
    """

    @staticmethod
    def composite(fn):
        def strategy(*_a, **_k):
            return None
        strategy.__name__ = fn.__name__
        return strategy

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
