"""Fused-execution suite: the counted-sync sweep computing real tiles.

The ladder of trust, bottom to top:

* :func:`reference_solve` — time-major NumPy, the ground truth;
* :func:`host_execute` — the *level-major* NumPy twin of the fused sweep
  (same tiles, same masking, same level order), proven **bitwise** equal
  to the reference — this is the argument that wavefront leveling
  linearizes every buffer hazard;
* :class:`FusedExecutor` replay and discover — the device sweeps, matched
  to the reference within documented tolerances (float32: rtol 1e-5 /
  atol 1e-6, observed ~1 ULP from XLA reassociation; float64: rtol 1e-12,
  observed ~1e-16) and to the host schedule frontiers **byte for byte**;
* :func:`handwritten_solve` — the no-task-graph jax baseline, agreeing
  with the reference under the same float32 tolerance.

Plus the failure modes (wrong body/tile/dtype, schedule-vs-packed
conflicts, dropped decrements stalling the fused discover sweep), the
graph-cache ``fused`` product, and the ≥1M-task jacobi2d acceptance run.
"""
from __future__ import annotations

from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest

from repro import compat
from repro.core.edt import (CachePolicy, ExecutionConfig, FusedExecutor,
                            GraphCache, Session, TiledTaskGraph,
                            graph_tile, host_execute, pack_origins,
                            simulate_indexed, synthesize_indexed)
from repro.core.edt.fused import SENTINEL_ORIGIN
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS
from repro.kernels.stencils import (SPECS, default_state, handwritten_solve,
                                    reference_solve)

#: (program, tile sizes, params) — every stencil body, small enough that
#: the sequential reference loop stays fast, big enough for partial tiles
#: (extents not multiples of tile sizes) and several wavefronts.
CASES = [
    ("stencil1d", (2, 2), {"T": 6, "N": 15}),
    ("jacobi2d", (2, 2, 2), {"T": 5, "N": 11}),
    ("heat3d", (2, 2, 2, 2), {"T": 3, "N": 7}),
    ("seidel1d", (2, 3), {"T": 6, "N": 14}),
]

F32_TOL = dict(rtol=1e-5, atol=1e-6)    # observed ~1 ULP (6e-8)
F64_TOL = dict(rtol=1e-12, atol=1e-13)  # observed ~1e-16


@pytest.fixture(scope="module")
def pool():
    p = ProcessPoolExecutor(max_workers=2)
    p.submit(int, 0).result()
    yield p
    p.shutdown()


def _graph(name, tiles):
    return TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                          backend="numpy")


# ===================================================== numerics ladder
@pytest.mark.parametrize("name,tiles,params", CASES)
def test_host_execute_bitwise_equals_reference(name, tiles, params):
    """Level-major tile execution == time-major execution, bit for bit:
    the wavefront levels linearize every parity-buffer hazard."""
    spec = SPECS[name]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    state = default_state(spec, params["N"], np.float32)
    got = host_execute(spec, tiles, params["T"], params["N"],
                       pack_origins(ig, tiles), sched.levels, state)
    want = reference_solve(spec, state, params["T"])
    assert got.dtype == want.dtype
    assert np.array_equal(got, want), name


@pytest.mark.parametrize("name,tiles,params", CASES)
@pytest.mark.parametrize("mode", ["replay", "discover"])
def test_fused_matches_reference_f32(name, tiles, params, mode):
    spec = SPECS[name]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    state = default_state(spec, params["N"], np.float32)
    ex = FusedExecutor(ig, params, body=name, tile=tiles,
                       schedule=sched if mode == "replay" else None,
                       state=state)
    run = ex.run()
    assert run.mode == mode
    want = reference_solve(spec, state, params["T"])
    np.testing.assert_allclose(run.final, want, **F32_TOL)
    # the non-answer parity buffer holds v_{T-2}
    if params["T"] >= 2:
        np.testing.assert_allclose(
            run.state[(params["T"] - 2) & 1],
            reference_solve(spec, state, params["T"] - 1), **F32_TOL)


@pytest.mark.parametrize("name,tiles,params", CASES)
def test_fused_matches_reference_f64(name, tiles, params):
    spec = SPECS[name]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    state = default_state(spec, params["N"], np.float64)
    want = reference_solve(spec, state, params["T"])
    with compat.enable_x64():
        for sched_arg in (sched, None):
            run = FusedExecutor(ig, params, body=name, tile=tiles,
                                schedule=sched_arg, state=state).run()
            np.testing.assert_allclose(run.final, want, **F64_TOL)


@pytest.mark.parametrize("name,tiles,params", CASES)
def test_handwritten_baseline_agrees(name, tiles, params):
    """The bench_fused baseline solves the same problem (so the priced
    comparison is apples to apples)."""
    spec = SPECS[name]
    state = default_state(spec, params["N"], np.float32)
    got = handwritten_solve(spec, state, params["T"])
    want = reference_solve(spec, state, params["T"])
    np.testing.assert_allclose(got, want, **F32_TOL)


def test_fused_custom_state_and_rerun():
    """run(state=) reuses the compiled sweep on fresh data."""
    name, tiles, params = CASES[0]
    spec = SPECS[name]
    g = _graph(name, tiles)
    ex = FusedExecutor(g, params)      # body/tile inferred from the graph
    s1 = default_state(spec, params["N"], np.float32)
    s2 = np.asarray(s1[::-1])
    np.testing.assert_allclose(ex.run(s1).final,
                               reference_solve(spec, s1, params["T"]),
                               **F32_TOL)
    np.testing.assert_allclose(ex.run(s2).final,
                               reference_solve(spec, s2, params["T"]),
                               **F32_TOL)


def test_zero_step_run_returns_initial_state():
    name, tiles, _ = CASES[0]
    spec = SPECS[name]
    state = default_state(spec, 9, np.float32)
    run = FusedExecutor(_graph(name, tiles), {"T": 0, "N": 9},
                        state=state).run()
    assert run.levels == [] and run.counters.depth == 0
    assert np.array_equal(run.final, state)


# ================================================== frontier identity
@pytest.mark.parametrize("name,tiles,params", CASES)
def test_fused_frontiers_byte_identical(name, tiles, params):
    """Both fused modes walk exactly the host schedule's frontiers — the
    compute never perturbs the counter sweep."""
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    runs = {
        "replay": FusedExecutor(ig, params, body=name, tile=tiles,
                                schedule=sched).run(),
        "discover": FusedExecutor(ig, params, body=name, tile=tiles).run(),
    }
    host_order = simulate_indexed(sched, workers=3).exec_order
    for label, run in runs.items():
        assert len(run.levels) == sched.depth, label
        for dev_lv, host_lv in zip(run.levels, sched.levels):
            assert dev_lv.dtype == host_lv.dtype, label
            assert np.array_equal(dev_lv, host_lv), label
        assert np.array_equal(run.level_of, sched.level_of), label
        assert run.exec_order.tolist() == host_order, label
        c = run.counters
        assert c.tasks_started == c.tasks_finished == ig.n, label
        assert c.depth == sched.depth, label
        assert c.max_in_flight == sched.max_width, label


def test_validate_false_same_answer():
    """Dropping the three violation counters changes nothing numeric."""
    name, tiles, params = CASES[1]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    a = FusedExecutor(ig, params, body=name, tile=tiles,
                      schedule=sched).run()
    b = FusedExecutor(ig, params, body=name, tile=tiles, schedule=sched,
                      validate=False).run()
    assert np.array_equal(a.final, b.final)
    assert np.array_equal(a.level_of, b.level_of)


def test_replay_rejects_corrupt_schedule():
    """The fused replay keeps the device executor's validation teeth."""
    from repro.core.edt import ScheduleValidationError
    from repro.core.edt.wavefront import IndexedSchedule, levels_from_array
    name, tiles, params = CASES[0]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    lv = sched.level_of.copy()
    lv[sched.levels[1][0]] += 2
    bad = IndexedSchedule(levels=levels_from_array(lv), level_of=lv)
    with pytest.raises(ScheduleValidationError):
        FusedExecutor(ig, params, body=name, tile=tiles, schedule=bad).run()


def test_dropped_decrement_stalls_fused_discover():
    """A dropped decrement (PR-6 fault plan) deadlocks the fused sweep
    loudly, with the structured stall report naming the context."""
    from repro.core.edt import Fault, FaultPlan, StallError
    from repro.core.edt.faults import DROPPED_DECREMENT
    name, tiles, params = CASES[0]
    g = _graph(name, tiles)
    plan = FaultPlan([Fault(DROPPED_DECREMENT, task=3)])
    ex = FusedExecutor(g, params, config=ExecutionConfig(faults=plan))
    with pytest.raises(StallError) as ei:
        ex.run()
    assert ei.value.report.context == "fused-discover"


# ======================================================= construction
def test_packed_layout_and_sentinel():
    name, tiles, params = CASES[1]
    g = _graph(name, tiles)
    ig = g.index_graph(params)
    fo = pack_origins(ig, tiles)
    assert fo.shape == (ig.n + 1, len(tiles)) and fo.dtype == np.int32
    assert (fo[-1] == SENTINEL_ORIGIN).all()
    _, coords = ig.stmt_blocks[0]
    assert np.array_equal(fo[:-1], coords * np.asarray(tiles))
    assert graph_tile(g) == tiles


def test_constructor_rejects_bad_inputs():
    name, tiles, params = CASES[0]
    g = _graph(name, tiles)
    ig, sched = synthesize_indexed(g, params)
    with pytest.raises(TypeError, match="params required"):
        FusedExecutor(g)
    with pytest.raises(TypeError, match="tile="):
        FusedExecutor(ig, params, body=name)
    with pytest.raises(TypeError, match="body="):
        FusedExecutor(ig, params, tile=tiles)
    with pytest.raises(TypeError, match="unknown stencil body"):
        FusedExecutor(ig, params, body="nope", tile=tiles)
    with pytest.raises(ValueError, match="tile dims"):
        FusedExecutor(ig, params, body=name, tile=(2, 2, 2))
    with pytest.raises(TypeError, match="not both"):
        FusedExecutor(ig, params, body=name, tile=tiles, schedule=sched,
                      packed=(None, None, None))
    with pytest.raises(TypeError, match="discover sweep only"):
        FusedExecutor(ig, params, body=name, tile=tiles, schedule=sched,
                      use_pallas=True)
    with pytest.raises(ValueError, match="state shape"):
        FusedExecutor(ig, params, body=name, tile=tiles,
                      state=np.zeros((3, 3), np.float32))
    # multi-statement graphs have no single tile body
    from repro.core.edt import IndexedGraph
    two = IndexedGraph(
        stmt_blocks=[("A", np.zeros((1, 2), np.int64)),
                     ("B", np.zeros((1, 2), np.int64))],
        n=2, edge_src=np.zeros(0, np.int64), edge_tgt=np.zeros(0, np.int64),
        pred_n=np.zeros(2, np.int64))
    with pytest.raises(ValueError, match="single-statement"):
        pack_origins(two, tiles)
    with pytest.raises(ValueError, match="do not match"):
        pack_origins(ig, (2, 2, 2))


def test_f64_without_x64_raises():
    import jax
    if jax.config.jax_enable_x64:          # pragma: no cover - env guard
        pytest.skip("suite running under global x64")
    name, tiles, params = CASES[0]
    ex = FusedExecutor(_graph(name, tiles), params, dtype=np.float64)
    with pytest.raises(RuntimeError, match="enable_x64"):
        ex.run()


def test_fused_discover_pallas_interpret():
    """The pallas decrement composes with the fused compute (interpret
    mode on this CPU container)."""
    if not compat.has_pallas():            # pragma: no cover - env guard
        pytest.skip("jax build has no pallas")
    name, tiles, params = CASES[0]
    spec = SPECS[name]
    g = _graph(name, tiles)
    state = default_state(spec, params["N"], np.float32)
    run = FusedExecutor(g, params, state=state, use_pallas=True,
                        interpret=True).run()
    np.testing.assert_allclose(
        run.final, reference_solve(spec, state, params["T"]), **F32_TOL)


# ============================================================== cache
def test_cache_fused_product_warm_by_reference():
    g = _graph("jacobi2d", (2, 2, 2))
    params = {"T": 4, "N": 10}
    cache = GraphCache(CachePolicy(incremental=False))
    cold = cache.fused(g, params)
    warm = cache.fused(g, params)
    for a, b in zip(cold, warm):
        assert a is b
    # the ig and tile are under the same fingerprint: bytes accounted
    assert cache.info()["bytes"] >= cold[2].nbytes


def test_cache_fused_respects_byte_budget():
    """The fo product participates in LRU eviction like the others."""
    g = _graph("stencil1d", (2, 2))
    budget = 30_000
    cache = GraphCache(CachePolicy(max_entries=64, max_bytes=budget,
                                   incremental=False))
    for n in range(8, 40, 2):
        cache.fused(g, {"T": 6, "N": n})
        assert cache.info()["bytes"] <= budget
    assert cache.info()["evictions"] > 0


def test_cache_disabled_fused_pass_through():
    g = _graph("stencil1d", (2, 2))
    cache = GraphCache(CachePolicy(enabled=False))
    a = cache.fused(g, {"T": 4, "N": 10})
    b = cache.fused(g, {"T": 4, "N": 10})
    assert a[2] is not b[2]
    assert np.array_equal(a[2], b[2])
    assert cache.info()["entries"] == 0


def test_session_fused_executor_end_to_end():
    """Session.fused_executor: warm products, correct numerics, both
    modes, and the packed arrays come back by reference."""
    name, tiles, params = CASES[1]
    spec = SPECS[name]
    g = _graph(name, tiles)
    with Session() as s:
        run = s.fused_executor(g, params).run()
        state = default_state(spec, params["N"], np.float32)
        np.testing.assert_allclose(
            run.final, reference_solve(spec, state, params["T"]), **F32_TOL)
        d = s.fused_executor(g, params, replay=False).run()
        assert d.mode == "discover"
        assert np.array_equal(d.final, run.final)
        p1 = s.fused_packed(g, params)
        p2 = s.fused_packed(g, params)
        for a, b in zip(p1, p2):
            assert a is b


# ========================================================== at scale
def test_million_task_jacobi2d_fused_acceptance(pool):
    """The ISSUE acceptance run: a ≥1M-task jacobi2d solve end to end on
    the fused executor — schedule validated on device, frontiers
    byte-identical to the host schedule, numerics within the documented
    float32 tolerance of the handwritten jax solve of the same problem
    (the full sequential NumPy reference is priced out at this size; the
    handwritten baseline is itself reference-checked at small sizes
    above)."""
    g = _graph("jacobi2d", (2, 2, 2))
    params = {"T": 32, "N": 512}
    ig, sched = synthesize_indexed(
        g, params, config=ExecutionConfig(shards=2, pool=pool))
    assert ig.n >= 1_000_000
    spec = SPECS["jacobi2d"]
    state = default_state(spec, params["N"], np.float32)
    run = FusedExecutor(ig, params, body="jacobi2d", tile=(2, 2, 2),
                        schedule=sched, state=state).run()   # validates
    assert run.counters.tasks_finished == ig.n
    assert run.counters.depth == sched.depth
    for dev_lv, host_lv in zip(run.levels, sched.levels):
        assert np.array_equal(dev_lv, host_lv)
    want = handwritten_solve(spec, state, params["T"])
    np.testing.assert_allclose(run.final, want, rtol=1e-4, atol=1e-5)
