"""Graph-cache suite: warm identity, byte budget, incremental stitching,
and service-level coalescing.

The contract under test (``docs/service.md``):

* a warm hit returns the *same* arrays a cold materialization produced —
  byte-identical across every generation backend including the sharded
  engine;
* the LRU never holds more than ``CachePolicy.max_bytes`` of arrays;
* incremental re-materialization (outer-param stitch from a cached donor)
  is byte-identical to a cold full scan;
* N concurrent :class:`ScheduleService` requests for one cold key run
  exactly one materialization.
"""
from __future__ import annotations

import asyncio
import threading
import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np
import pytest

from repro.core.edt import (CachePolicy, ExecutionConfig, GraphCache,
                            ScheduleService, Session, graph_cache_info)
from repro.core.edt.taskgraph import TiledTaskGraph
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS

BACKENDS = ("fraction", "compiled", "numpy")


@pytest.fixture(scope="module")
def pool():
    p = ProcessPoolExecutor(max_workers=2)
    p.submit(int, 0).result()
    yield p
    p.shutdown()


def _graph(name="jacobi2d", tiles=(2, 2, 2), backend="numpy"):
    return TiledTaskGraph(PROGRAMS[name](), {"S": Tiling(tiles)},
                          backend=backend)


def _assert_ig_identical(a, b):
    assert a.n == b.n and a.n_edges == b.n_edges
    assert np.array_equal(a.edge_src, b.edge_src)
    assert np.array_equal(a.edge_tgt, b.edge_tgt)
    assert np.array_equal(a.pred_n, b.pred_n)
    for (na, xa), (nb, xb) in zip(a.stmt_blocks, b.stmt_blocks):
        assert na == nb and np.array_equal(xa, xb)


# ======================================================== warm identity
@pytest.mark.parametrize("backend", BACKENDS)
def test_warm_hit_identical_to_cold(backend):
    """Warm products are the cold products — same objects, same bytes —
    for every scanning backend."""
    g = _graph(backend=backend)
    params = {"T": 4, "N": 16}
    cache = GraphCache(CachePolicy(incremental=False))
    cold = cache.graph(g, params)
    oracle = g.index_graph(params)     # uncached reference
    _assert_ig_identical(cold, oracle)
    warm = cache.graph(g, params)
    assert warm is cold                # by-reference warm hit
    ig, sched = cache.schedule(g, params)
    assert ig is cold
    ig2, sched2 = cache.schedule(g, params)
    assert sched2 is sched
    dg, ds = cache.packed(g, params)
    dg2, ds2 = cache.packed(g, params)
    assert dg2 is dg and ds2 is ds
    assert cache.info()["hits"] >= 4


def test_warm_hit_identical_to_cold_sharded(pool):
    """The sharded engine fills the cache with the same arrays the
    in-process scan produces; the warm hit returns them by reference."""
    g = _graph()
    params = {"T": 4, "N": 16}
    cfg = ExecutionConfig(shards=2, pool=pool)
    cache = GraphCache()
    cold = cache.graph(g, params, cfg)
    _assert_ig_identical(cold, g.index_graph(params))
    assert cache.graph(g, params, cfg) is cold


def test_fingerprint_distinguishes_programs_not_backends():
    """Identical programs share a fingerprint across backends (the cache
    key is the *parametric program*); different programs never collide."""
    fps = {b: _graph(backend=b).fingerprint() for b in BACKENDS}
    assert len(set(fps.values())) == 1
    assert _graph("trisolv", (4, 4)).fingerprint() != fps["numpy"]
    assert _graph(tiles=(2, 2, 4)).fingerprint() != fps["numpy"]


# ========================================================= byte budget
def test_eviction_respects_byte_budget():
    """The cache never exceeds max_bytes; LRU entries evict whole."""
    g = _graph("trisolv", (4, 4))
    budget = 20_000
    cache = GraphCache(CachePolicy(max_entries=64, max_bytes=budget,
                                   incremental=False))
    for n in range(8, 32, 2):
        cache.packed(g, {"N": n})
        assert cache.info()["bytes"] <= budget
    info = cache.info()
    assert info["evictions"] > 0
    assert info["entries"] < 12        # the budget actually bit


def test_max_entries_bounds_lru():
    g = _graph("trisolv", (4, 4))
    cache = GraphCache(CachePolicy(max_entries=3, incremental=False))
    for n in range(8, 20, 2):
        cache.graph(g, {"N": n})
    assert cache.info()["entries"] <= 3
    # most-recent key is still warm
    hits0 = cache.info()["hits"]
    cache.graph(g, {"N": 18})
    assert cache.info()["hits"] == hits0 + 1


def test_disabled_cache_is_pass_through():
    g = _graph("trisolv", (4, 4))
    cache = GraphCache(CachePolicy(enabled=False))
    a = cache.graph(g, {"N": 10})
    b = cache.graph(g, {"N": 10})
    assert a is not b
    _assert_ig_identical(a, b)
    assert cache.info()["entries"] == 0


# ======================================================== incremental
@pytest.mark.parametrize("name,tiles,old,new", [
    ("jacobi2d", (2, 2, 2), {"T": 6, "N": 12}, {"T": 9, "N": 12}),   # grow T
    ("jacobi2d", (2, 2, 2), {"T": 9, "N": 12}, {"T": 5, "N": 12}),   # shrink T
    ("stencil1d", (2, 2), {"T": 8, "N": 14}, {"T": 12, "N": 14}),
    ("trisolv", (4, 4), {"N": 20}, {"N": 28}),
])
def test_incremental_matches_full_rescan(name, tiles, old, new):
    """Outer-param change: the stitched graph equals a cold scan, and the
    stitch actually ran (incremental_hits advanced)."""
    g = _graph(name, tiles)
    cache = GraphCache()
    cache.graph(g, old)                       # donor
    inc = cache.graph(g, new)                 # stitched
    assert cache.info()["incremental_hits"] == 1
    assert cache.info()["units_reused"] >= 1
    _assert_ig_identical(inc, _graph(name, tiles).index_graph(new))


def test_incremental_falls_back_when_param_bounds_inner_dims():
    """diamond's K bounds both loop dims — nothing is outer-only, so the
    cache must fall back to a full re-scan (and still be correct)."""
    g = _graph("diamond", (2, 2))
    cache = GraphCache()
    cache.graph(g, {"K": 8})
    ig = cache.graph(g, {"K": 12})
    assert cache.info()["incremental_hits"] == 0
    _assert_ig_identical(ig, _graph("diamond", (2, 2)).index_graph({"K": 12}))


def test_incremental_schedule_and_packed_still_correct():
    """Products derived from a stitched graph (levels, device columns)
    equal those derived from a cold graph."""
    g = _graph()
    old, new = {"T": 6, "N": 12}, {"T": 8, "N": 12}
    cache = GraphCache()
    cache.packed(g, old)
    dg, ds = cache.packed(g, new)
    assert cache.info()["incremental_hits"] == 1
    ig_cold, sched_cold = _graph().index_graph(new), None
    from repro.core.edt import schedule_from_graph
    sched_cold = schedule_from_graph(ig_cold)
    assert np.array_equal(ds.level_of, sched_cold.level_of)
    assert np.array_equal(np.sort(dg.succ), np.sort(ig_cold.edge_tgt))


# ========================================================== coalescing
def test_concurrent_service_requests_materialize_once():
    """N clients, one cold key: exactly one materialization runs; every
    client gets the same object."""
    g = _graph("trisolv", (4, 4))
    calls = []
    inner = g._index_graph_cfg

    def counting(params, cfg, scans=None):
        calls.append(dict(params))
        return inner(params, cfg, scans=scans)

    g._index_graph_cfg = counting

    async def burst(service, n):
        return await asyncio.gather(
            *(service.schedule(g, {"N": 24}) for _ in range(n)))

    with Session() as session:
        service = ScheduleService(session)
        try:
            results = asyncio.run(burst(service, 8))
        finally:
            service.close()
        assert len(calls) == 1
        igs = {id(ig) for ig, _ in results}
        assert len(igs) == 1
        stats = service.stats()
        assert stats["cold"] == 1
        assert stats["coalesced"] == 7
        # warm pass: no new materialization, no executor hop
        service2 = ScheduleService(session)
        try:
            asyncio.run(burst(service2, 4))
        finally:
            service2.close()
        assert len(calls) == 1
        assert service2.stats()["warm"] == 4


def test_service_distinct_keys_fill_independently():
    g = _graph("trisolv", (4, 4))

    async def go(service):
        return await service.batch(g, [{"N": 16}, {"N": 20}, {"N": 16}])

    service = ScheduleService(config=ExecutionConfig())
    try:
        a, b, a2 = asyncio.run(go(service))
        assert a[0] is a2[0]
        assert a[0] is not b[0]
        stats = service.stats()
        assert stats["cold"] == 2
        assert stats["warm"] + stats["coalesced"] == 1
    finally:
        service.close()


def test_service_frontiers_stream_matches_schedule():
    g = _graph("trisolv", (4, 4))

    async def go(service):
        levels = [lv async for lv in service.frontiers(g, {"N": 16})]
        _, sched = await service.schedule(g, {"N": 16})
        return levels, sched

    service = ScheduleService(config=ExecutionConfig())
    try:
        levels, sched = asyncio.run(go(service))
        assert len(levels) == len(sched.levels)
        for got, want in zip(levels, sched.levels):
            assert np.array_equal(got, want)
    finally:
        service.close()


# ===================================================== param-key hygiene
def test_params_key_normalizes_scalar_types():
    """``np.int64(24)`` (a sharded merge), ``24`` (a direct call), and
    ``24.0`` (JSON) key one entry — the key holds plain Python ints."""
    from repro.core.edt.cache import _params_key
    a = _params_key({"N": 24, "T": 4})
    b = _params_key({"T": np.int64(4), "N": np.float64(24.0)})
    assert a == b
    assert all(type(v) is int for _, v in b)
    assert type(_params_key({"flag": np.bool_(True)})[0][1]) is bool
    assert _params_key({"x": 2.5}) == (("x", 2.5),)   # non-integral floats


def test_params_key_rejects_unhashable_with_named_param():
    from repro.core.edt.cache import _params_key
    with pytest.raises(TypeError, match="'N'.*unhashable"):
        _params_key({"N": [24]})
    with pytest.raises(TypeError, match="'tiles'"):
        _params_key({"N": 24, "tiles": {"S": 2}})


def test_cache_mixed_scalar_types_share_one_entry():
    """Regression: numpy-scalar params used to be able to shadow the
    Python-int entry; now they are one warm key."""
    g = _graph("trisolv", (4, 4))
    cache = GraphCache(CachePolicy(incremental=False))
    cold = cache.graph(g, {"N": 24})
    assert cache.graph(g, {"N": np.int64(24)}) is cold
    assert cache.graph(g, {"N": np.float64(24.0)}) is cold
    assert cache.info()["entries"] == 1
    assert cache.info()["hits"] == 2


# =============================================== service warm-path race
def test_lookup_product_is_atomic_under_eviction():
    """Regression: the service's warm path used to peek one field and then
    re-fetch the product, racing eviction between the two probes.  One
    ``lookup_product`` call returns the *whole* product by reference under
    the cache lock — an eviction landing right after it cannot claw the
    arrays back."""
    g = _graph("trisolv", (4, 4))
    cache = GraphCache(CachePolicy(incremental=False))
    ig, sched = cache.schedule(g, {"N": 16})
    got = cache.lookup_product(g, {"N": 16}, "schedule")
    cache.clear()                     # the eviction lands after the probe
    assert got is not None
    got_ig, got_sched = got
    assert got_ig is ig and got_sched is sched
    # a partially-filled entry is never a warm product
    cache.graph(g, {"N": 20})         # ig cached, schedule not
    assert cache.lookup_product(g, {"N": 20}, "schedule") is None
    assert cache.lookup_product(g, {"N": 20}, "graph") is not None


def test_service_warm_path_never_fills_on_the_loop_under_eviction():
    """Eviction storm (budget admits one entry, two keys alternate): every
    materialization must run on the service executor — the loop thread
    never blocks on a scan, no matter how the warm probe races."""
    g = _graph("trisolv", (4, 4))
    fill_threads = []
    inner = g._index_graph_cfg

    def counting(params, cfg, scans=None):
        fill_threads.append(threading.current_thread().name)
        return inner(params, cfg, scans=scans)

    g._index_graph_cfg = counting
    try:
        session = Session(ExecutionConfig(
            cache=CachePolicy(max_entries=1, incremental=False)))
        service = ScheduleService(session)

        async def storm():
            for _ in range(4):
                await service.schedule(g, {"N": 16})
                await service.schedule(g, {"N": 20})   # evicts N=16

        asyncio.run(storm())
        assert len(fill_threads) == 8            # every request re-fills
        assert all(t.startswith("edt-serve") for t in fill_threads)
        stats = service.stats()
        assert stats["cold"] == 8 and stats["warm"] == 0
        service.close()
        session.close()
    finally:
        g._index_graph_cfg = inner


# ================================================== service close() drain
def test_close_drains_inflight_then_tears_down():
    """Regression: ``close()`` used to shut the executor down under live
    fills.  Now it refuses new requests, waits for every in-flight fill,
    and resolves already-awaiting clients normally — and it is idempotent."""
    g = _graph("trisolv", (4, 4))
    started, release = threading.Event(), threading.Event()
    inner = g._index_graph_cfg

    def slow(params, cfg, scans=None):
        started.set()
        release.wait(10)
        return inner(params, cfg, scans=scans)

    g._index_graph_cfg = slow
    service = ScheduleService(config=ExecutionConfig())
    results = {}
    try:
        client = threading.Thread(
            target=lambda: results.update(
                r=asyncio.run(service.schedule(g, {"N": 24}))))
        client.start()
        assert started.wait(10)               # the fill is in flight
        closer = threading.Thread(target=service.close)
        closer.start()
        time.sleep(0.1)
        assert closer.is_alive()              # close is draining, not axing
        release.set()
        closer.join(10)
        client.join(10)
        assert not closer.is_alive()
        assert "r" in results                 # awaiting client resolved
        assert results["r"][1].depth > 0
    finally:
        release.set()
        g._index_graph_cfg = inner
    service.close()                           # idempotent second close
    with pytest.raises(RuntimeError, match="closed"):
        asyncio.run(service.schedule(g, {"N": 30}))


def test_close_with_no_inflight_is_clean():
    service = ScheduleService(config=ExecutionConfig())
    service.close()
    service.close()
    with pytest.raises(RuntimeError, match="closed"):
        asyncio.run(service.index_graph(_graph("trisolv", (4, 4)),
                                        {"N": 12}))


# ====================================================== introspection
def test_graph_cache_info_aggregates():
    g = _graph("trisolv", (4, 4))
    cache = GraphCache()
    before = graph_cache_info()
    cache.graph(g, {"N": 12})
    cache.graph(g, {"N": 12})
    after = graph_cache_info()
    assert after["hits"] >= before["hits"] + 1
    assert after["entries"] >= 1
