"""Batched serving example: prefill a batch of prompts, decode with caches.

    PYTHONPATH=src python examples/serve_decode.py

Drives three different architecture families through the same serving API:
a dense GQA model, the MLA (compressed-cache) model, and the attention-free
RWKV6 — demonstrating that the cache abstraction covers KV caches,
low-rank latent caches, and constant-size recurrent state.
"""
import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model

ARCHS = ["llama3.2-1b", "deepseek-v3-671b", "rwkv6-1.6b"]


def main():
    for arch in ARCHS:
        cfg = get_config(arch).smoke_config().replace(remat=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        B, Lp, G = 4, 16, 16
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 0,
                                     cfg.vocab).astype(jnp.int32)
        caches = model.init_cache(B, Lp + G + 1, jnp.float32)

        @jax.jit
        def prefill(params, caches, toks):
            logits, caches = model.forward(params, toks, caches=caches,
                                           pos_offset=0)
            return logits[:, -1], caches

        @jax.jit
        def step(params, caches, tok, pos):
            return model.decode_step(params, tok, caches, pos)

        logits, caches = prefill(params, caches, prompts)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.time()
        toks = [tok]
        for i in range(G - 1):
            logits, caches = step(params, caches, tok, Lp + i)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(tok)
        dt = (time.time() - t0) / (G - 1) * 1e3
        cache_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(caches))
        print(f"{arch:20s} decode {dt:6.1f} ms/step  "
              f"cache={cache_bytes/1e6:.2f} MB  "
              f"sample={[int(t[0,0]) for t in toks[:6]]}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
