"""The paper end-to-end: compile a stencil to an event-driven task program.

    PYTHONPATH=src python examples/stencil_edt.py

1. Build the Jacobi-1D polyhedral program (time-skewed, as the affine
   scheduler would emit it).
2. Tile it; compute inter-tile dependences with §3 compression (printing the
   generated code of Figs 3/4/5 for each synchronization model).
3. Execute the REAL stencil through the EDT runtime (threaded autodec —
   atomic get-or-create, preschedule, O(1) startup) and check the result
   against a dense jnp reference.
4. Compare overhead counters across all five synchronization models.
5. Run the same schedule device-resident: pack the index graph into jax
   arrays and sweep the counted-sync loop on the DeviceExecutor (discover
   and replay modes), checking its frontiers against the host wavefront
   synthesis — docs/device_exec.md.
6. Fuse the tile bodies into that sweep: one jitted XLA program both
   decrements the counters and computes every tile (FusedExecutor),
   checked against the NumPy reference solve — docs/device_exec.md,
   "Fused execution".
"""
import sys
sys.path.insert(0, "src")

import time

import numpy as np

from repro.core.edt import (MODELS, DeviceExecutor, FusedExecutor,
                            TiledTaskGraph, run_model, synthesize_indexed,
                            ThreadedAutodec, validate_order)
from repro.core.edt.codegen import (emit_autodec, emit_fused,
                                    emit_prescribed, emit_tags)
from repro.core.poly import Tiling
from repro.core.programs import PROGRAMS
from repro.kernels.stencils import SPECS, default_state, reference_solve

T_STEPS, N = 12, 64
TILE = (3, 8)


def main():
    prog = PROGRAMS["stencil1d"]()
    graph = TiledTaskGraph(prog, {"S": Tiling(TILE)})
    params = {"T": T_STEPS, "N": N}
    n = graph.num_tasks(params)
    print(f"Jacobi-1D (skewed): {T_STEPS}x{N} iters -> {n} tasks "
          f"(tile {TILE}), strategies: {graph.pred_count_strategies()}\n")

    print(emit_prescribed(graph), "\n")
    print(emit_tags(graph, method=2), "\n")
    print(emit_autodec(graph), "\n")

    # ---- execute the actual stencil through the autodec runtime ----------
    # state[t % 2] holds the field at time t; tiles update their (t, x) cells
    field = [np.zeros(N + 2 * T_STEPS), np.zeros(N + 2 * T_STEPS)]
    field[0][:] = np.linspace(0, 1, N + 2 * T_STEPS)
    field[1][:] = field[0]          # ping-pong halo must start identical
    init = field[0].copy()

    def body(task):
        _, (tT, xT) = task
        for t in range(tT * TILE[0], (tT + 1) * TILE[0]):
            if not (0 <= t < T_STEPS):
                continue
            src, dst = field[t % 2], field[(t + 1) % 2]
            for x in range(xT * TILE[1], (xT + 1) * TILE[1]):
                i = x - t          # unskew
                if 0 <= i < N:
                    j = i + T_STEPS   # halo offset
                    dst[j] = 0.25 * src[j - 1] + 0.5 * src[j] + 0.25 * src[j + 1]

    rt = ThreadedAutodec(
        pred_count=lambda t: graph.pred_count(t, params),
        successors=lambda t: list(graph.successors(t, params)),
        body=body, workers=1)   # single worker: in-place halo updates race-free
    rt.preschedule_all(graph.tasks(params))
    assert rt.wait(120)
    rt.shutdown()
    assert not rt.errors, rt.errors[:1]

    ref = init.copy()
    for _ in range(T_STEPS):
        nxt = ref.copy()
        nxt[T_STEPS:T_STEPS + N] = (0.25 * ref[T_STEPS - 1:T_STEPS + N - 1]
                                    + 0.5 * ref[T_STEPS:T_STEPS + N]
                                    + 0.25 * ref[T_STEPS + 1:T_STEPS + N + 1])
        ref = nxt
    got = field[T_STEPS % 2]
    np.testing.assert_allclose(got[T_STEPS:T_STEPS + N],
                               ref[T_STEPS:T_STEPS + N], rtol=1e-12)
    print(f"EDT execution matches dense reference on {N} cells "
          f"x {T_STEPS} steps (tasks executed: {len(rt.executed)})\n")

    # ---- Table 2 in practice ---------------------------------------------
    print(f"{'model':15s} {'startup':>8s} {'spatial':>8s} {'in-flight':>10s} "
          f"{'deps':>6s} {'garbage':>8s} {'makespan':>9s}")
    for model in MODELS:
        res = run_model(model, graph, params, workers=4, setup_cost=0.02)
        validate_order(graph, params, res)
        s = res.counters.summary()
        print(f"{model:15s} {s['startup_ops']:8d} {s['spatial_peak']:8d} "
              f"{s['inflight_tasks_peak']:10d} {s['inflight_deps_peak']:6d} "
              f"{s['garbage_peak']:8d} {s['makespan']:9.2f}")

    # ---- device-resident wavefront execution ------------------------------
    # The same tile graph as flat index arrays on the jax layer: the counted
    # model's counters live in device memory and the whole schedule sweeps
    # in one XLA loop — no host dicts, no per-task Python dispatch.
    dgraph = TiledTaskGraph(prog, {"S": Tiling(TILE)}, backend="numpy")
    ig, sched = synthesize_indexed(dgraph, params)
    for mode, kw in (("discover", {}), ("replay", {"schedule": sched})):
        dev = DeviceExecutor(ig, **kw)
        dev.run()                       # compile
        t0 = time.perf_counter()
        drun = dev.run()                # warm: the dispatch cost
        dt = time.perf_counter() - t0
        assert len(drun.levels) == sched.depth
        assert all(np.array_equal(a, b)
                   for a, b in zip(drun.levels, sched.levels))
        c = drun.counters.summary()
        print(f"\ndevice {mode:9s}: {c['tasks_finished']} tasks in "
              f"{c['depth']} wavefronts (max in-flight {c['max_in_flight']}) "
              f"— frontiers identical to host synthesis, "
              f"{1e6 * dt / max(1, ig.n):.1f} us/task dispatch")

    # ---- fused: the tiles compute inside the sweep -------------------------
    # Same packed schedule, but now each wavefront also executes its tiles'
    # stencil taps on a device-resident parity-buffered grid; the host sees
    # nothing until the final readback.
    print("\n" + emit_fused(dgraph), "\n")
    spec = SPECS["stencil1d"]
    state = default_state(spec, N, np.float32)
    fused = FusedExecutor(dgraph, params, schedule=sched, state=state)
    fused.run()                         # compile
    t0 = time.perf_counter()
    frun = fused.run()                  # warm
    dt = time.perf_counter() - t0
    np.testing.assert_allclose(frun.final,
                               reference_solve(spec, state, T_STEPS),
                               rtol=1e-5, atol=1e-6)
    assert all(np.array_equal(a, b)
               for a, b in zip(frun.levels, sched.levels))
    print(f"fused replay    : {frun.counters.tasks_finished} tasks computed "
          f"AND synchronized in {frun.counters.depth} wavefronts, result "
          f"matches the NumPy reference, "
          f"{1e6 * dt / max(1, ig.n):.1f} us/task")
    print("\nstencil_edt OK")


if __name__ == "__main__":
    main()
