"""Pipeline-parallel training scheduled by the polyhedral EDT machinery.

    PYTHONPATH=src python examples/pipeline_train.py

Runs on 8 virtual devices (host platform): 4 pipeline stages x 2 data.
The (microbatch, stage) wavefront schedule is *derived* from the paper's
compression-based tile dependences (see repro/parallel/pipeline.py), lowered
to shard_map + ppermute, and differentiated straight through for training —
the backward wavefront is the VJP of the forward one.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import (build_schedule, make_pipeline_loss,
                                     pipelined_forward, sequential_reference)

N_STAGES = 4
N_MICRO = 8
TILE_M = 2
D = 64
B_TILE = 4


def stage_fn(p, x):
    """One pipeline stage: a two-layer MLP block (residual)."""
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return x + h @ p["w2"]


def main():
    mesh = jax.make_mesh((N_STAGES,), ("stage",))
    sched = build_schedule(N_MICRO, N_STAGES, tile_m=TILE_M)
    print(f"polyhedral schedule: {sched.n_tiles} microbatch tiles x "
          f"{sched.n_stages} stages -> {sched.depth} wavefronts "
          f"(= M' + S - 1 = {sched.n_tiles + N_STAGES - 1})")

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "w1": 0.3 * jax.random.normal(k1, (N_STAGES, D, D)),
        "b1": jnp.zeros((N_STAGES, D)),
        "w2": 0.3 * jax.random.normal(k2, (N_STAGES, D, D)),
    }
    mbs = jax.random.normal(k3, (sched.n_tiles, B_TILE * TILE_M, D))

    # 1. forward correctness vs the sequential oracle
    out_pipe = pipelined_forward(stage_fn, params, mbs, sched, mesh)
    out_ref = sequential_reference(stage_fn, params, mbs)
    np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)
    print("pipelined forward == sequential reference")

    # 2. train through the pipeline (grad flows through ppermute)
    targets = jax.random.normal(k4, out_ref.shape)
    loss_fn = make_pipeline_loss(stage_fn, sched, mesh)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 0.05
    losses = []
    for step in range(30):
        loss, g = grad_fn(params, mbs, targets)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        losses.append(float(loss))
    print(f"pipeline training loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0] * 0.7, losses
    print("pipeline_train OK")


if __name__ == "__main__":
    main()
