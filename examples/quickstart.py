"""Quickstart: train a tiny LM for a few steps, checkpoint, restore, decode.

    PYTHONPATH=src python examples/quickstart.py

Uses the public API only: configs registry -> build_model -> TrainDriver
(prefetch + async checkpoint + restart) -> incremental decoding.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.runtime import DriverConfig, TrainDriver


def main():
    cfg = get_config("llama3.2-1b").smoke_config().replace(
        d_model=128, d_ff=256, n_layers=2, vocab=512, remat=False)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=2e-3, warmup=10, total_steps=60)

    def init_fn():
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        return params, init_state(opt_cfg, params)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        params, opt_state = apply_updates(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    driver = TrainDriver(
        DriverConfig(total_steps=60, ckpt_every=20,
                     ckpt_dir="/tmp/repro_quickstart"),
        data_cfg, train_step, init_fn)
    hist = driver.run()
    print(f"loss: {hist[0].loss:.3f} -> {hist[-1].loss:.3f} "
          f"over {len(hist)} steps")
    assert hist[-1].loss < hist[0].loss

    # restore the last checkpoint and decode a few tokens
    from repro.checkpoint import latest_step, restore
    params, opt_state = init_fn()
    step = latest_step("/tmp/repro_quickstart")
    state = restore("/tmp/repro_quickstart", step,
                    {"params": params, "opt": opt_state})
    params = state["params"]

    caches = model.init_cache(2, 64, jnp.float32)
    toks = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    _, caches = model.forward(params, toks, caches=caches, pos_offset=0)
    tok = jnp.array([[7], [8]], jnp.int32)
    out = []
    for i in range(8):
        logits, caches = model.decode_step(params, tok, caches, 3 + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation:", out)
    print("quickstart OK")


if __name__ == "__main__":
    main()
