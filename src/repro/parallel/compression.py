"""Gradient compression for the data-parallel all-reduce.

int8 reduce-scatter + all-gather with f32 accumulation: each gradient is
block-quantized to int8 (per-256-element scales), exchanged over the data
axis with `all_to_all` (the reduce-scatter half), summed locally in f32,
re-quantized, and all-gathered.  Wire bytes drop ~3.6x vs f32 all-reduce
(int8 payload + f32 scales), visible directly in the dry-run's collective
byte counts — this is a §Perf lever for collective-bound cells.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any
BLOCK = 256


def _quant(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), 1, keepdims=True), 1e-12)
    q = jnp.clip(jnp.round(blocks / scale * 127), -127, 127).astype(jnp.int8)
    return q, (scale / 127).astype(jnp.float32)


def _dequant(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:size].reshape(shape)


def compressed_psum_grads(grads: PyTree, mesh: Mesh, axis: str = "data"):
    """Mean-reduce gradients over ``axis`` with int8 wire format.

    Call on *unreduced* (per-shard) gradients inside shard_map, or use
    ``make_compressed_allreduce`` to wrap at the pjit level.
    """
    n = mesh.shape[axis]

    def one(g):
        shape, size = g.shape, g.size
        q, s = _quant(g.astype(jnp.float32))
        nb = q.shape[0]
        padb = (-nb) % n
        if padb:
            q = jnp.pad(q, ((0, padb), (0, 0)))
            s = jnp.pad(s, ((0, padb), (0, 0)))
        # reduce-scatter half: everyone sends its i-th block-slab to rank i
        qs = q.reshape(n, -1, BLOCK)
        ss = s.reshape(n, -1, 1)
        qr = jax.lax.all_to_all(qs, axis, 0, 0)          # [n, nb/n, B]
        sr = jax.lax.all_to_all(ss, axis, 0, 0)
        local = (qr.astype(jnp.float32) * sr).sum(0) / n  # f32 accumulation
        q2, s2 = _quant(local)
        # all-gather half
        qg = jax.lax.all_gather(q2, axis)                 # [n, nb/n, B]
        sg = jax.lax.all_gather(s2, axis)
        full_q = qg.reshape(-1, BLOCK)[:nb + padb][:nb]
        full_s = sg.reshape(-1, 1)[:nb + padb][:nb]
        return _dequant(full_q, full_s, shape, size).astype(g.dtype)

    return jax.tree.map(one, grads)


def make_compressed_allreduce(mesh: Mesh, dp_spec, axis: str = "data"):
    """pjit-level wrapper: grads come in dp-replicated? No — this expects
    per-dp-shard *partial* grads produced inside a shard_map loss; for the
    pjit flow use quantize-dequantize before the implicit all-reduce
    (``simulate=True``), which models the precision (not the bandwidth)."""

    def apply(grads):
        def region(g):
            return compressed_psum_grads(g, mesh, axis)
        raise NotImplementedError(
            "use compressed_psum_grads inside a shard_map training region")

    return apply


def quantize_dequantize_grads(grads: PyTree) -> PyTree:
    """Precision-only model of int8 gradient exchange (pjit-compatible)."""
    def one(g):
        q, s = _quant(g.astype(jnp.float32))
        return _dequant(q, s, g.shape, g.size).astype(g.dtype)
    return jax.tree.map(one, grads)
