"""Sharding rules: pytree-path pattern -> PartitionSpec, per architecture.

Axis conventions (see launch/mesh.py):
  'data' (+ 'pod' when multi-pod)  — batch / ZeRO axis
  'model'                          — TP / EP / head axis

Rules are (regex over the flattened path, spec builder).  Param tensors are
stacked per layer ([L, ...] leading dim), so most specs start with None.
The same rules shard the AdamW moment tree (MomentState mirrors the param
shapes; 8-bit states are flat [nblocks, 256] and get ZeRO 'data' sharding).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


# (pattern, spec-for-trailing-dims); leading L dim (if rank is +1) gets None.
# Specs are written for the *unstacked* tensor rank.
_RULES: list[tuple[str, tuple]] = [
    # embeddings: vocab-parallel over model axis
    (r"embed$", ("model", None)),
    (r"unembed$", (None, "model")),
    (r"enc_pos$", (None, None)),
    # attention (GQA + cross-attention)
    (r"attn/w[qkv]$|xattn/w[qkv]$", (None, "model")),
    (r"attn/wo$|xattn/wo$", ("model", None)),
    (r"attn/b[qkv]$", ("model",)),
    # MLA
    (r"attn/wdq$|attn/wdkv$|attn/wkr$", (None, None)),
    (r"attn/wuq$|attn/wuk$|attn/wuv$", (None, "model")),
    (r"attn/(q|kv)_norm$", (None,)),
    # dense MLPs
    (r"mlp/w[gu1]$|shared/w[gu1]$", (None, "model")),
    (r"mlp/w[d2]$|shared/w[d2]$", ("model", None)),
    # MoE experts: expert-parallel; big expert counts shard E over
    # (data x model) so 256-expert models distribute across the full pod
    (r"moe/w[gu]$", (("data", "model"), None, None)),
    (r"moe/wd$", (("data", "model"), None, None)),
    (r"moe/router$", (None, None)),
    # Mamba2
    (r"mamba/win$", (None, "model")),
    (r"mamba/wout$", ("model", None)),
    (r"mamba/conv$", (None, "model")),
    (r"mamba/(A_log|D|dt_bias)$", (None,)),
    (r"mamba/norm$", (None,)),
    # RWKV6
    (r"mix/w[rkvg]$|mix/wo$|mix/cr$", (None, "model")),
    (r"mix/ck$", (None, "model")),
    (r"mix/cv$", ("model", None)),
    (r"mix/w_lora_a$", (None, None)),
    (r"mix/w_lora_b$", (None, None)),
    (r"mix/u$", (None, None)),
    (r"mix/(mix_rkvwg|mix_cm|w0|ln_x)$", None),  # replicate small vectors
    # norms and everything small: replicate
    (r"ln", None),
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis if a in mesh.shape]))
    return mesh.shape.get(axis, 1) if isinstance(mesh.shape, dict) else mesh.shape[axis]


def _fit_axis(axis, dim: int, mesh: Mesh):
    """Largest suffix/whole of the requested axis (or None) that divides."""
    if axis is None:
        return None
    candidates = [axis]
    if isinstance(axis, tuple):
        # prefer the full product, then each single member (model first)
        candidates += [a for a in reversed(axis)]
    for cand in candidates:
        csize = _axis_size(mesh, cand)
        ok = dim % csize == 0
        if isinstance(cand, tuple):
            ok = ok and all(a in mesh.axis_names for a in cand)
        else:
            ok = ok and (cand in mesh.axis_names)
        if ok and csize > 1:
            return cand
    return None


def spec_for_param(path: str, shape: tuple[int, ...],
                   mesh: Mesh) -> P:
    for pat, trailing in _RULES:
        if re.search(pat, path):
            if trailing is None:
                return P()
            rank = len(shape)
            spec = list(trailing)
            # leading stack dims (L, or none) -> None
            while len(spec) < rank:
                spec.insert(0, None)
            spec = spec[-rank:] if len(spec) > rank else spec
            out = [_fit_axis(ax, dim, mesh) for ax, dim in zip(spec, shape)]
            return P(*out)
    return P()  # default: replicate


def zero_spec(spec: P, shape: tuple[int, ...], mesh: Mesh,
              enable: bool = True) -> P:
    """ZeRO: additionally shard a replicated axis over the *unused* dp axes.

    Applied to optimizer moments (and optionally params for ZeRO-3).
    Picks the first unsharded dim divisible by the free dp extent.
    """
    if not enable:
        return spec
    spec_t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    used: set = set()
    for ax in spec_t:
        if isinstance(ax, tuple):
            used.update(ax)
        elif ax is not None:
            used.add(ax)
    dps = tuple(a for a in dp_axes(mesh) if a not in used)
    if not dps:
        return P(*spec_t)
    dp_n = int(np.prod([mesh.shape[a] for a in dps]))
    out = list(spec_t)
    for i, (ax, dim) in enumerate(zip(spec_t, shape)):
        if ax is None and dim % dp_n == 0:
            out[i] = dps if len(dps) > 1 else dps[0]
            return P(*out)
    return P(*spec_t)


def param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    def one(path, x):
        return spec_for_param(_path_str(path), x.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_specs(opt_state: PyTree, param_spec_tree: PyTree, mesh: Mesh,
                    zero: bool = True) -> PyTree:
    """Moments follow the param spec (+ZeRO); 8-bit blocks shard over data."""
    from ..optim import MomentState

    flat_p, treedef = jax.tree.flatten(param_spec_tree,
                                       is_leaf=lambda x: isinstance(x, P))
    flat_mv = treedef.flatten_up_to(opt_state["mv"])

    def mv_spec(pspec: P, mv: MomentState):
        if mv.m_scale is not None:
            # shape-preserving 8-bit moments: int8 inherits the param spec;
            # the per-block scale drops the last-axis sharding if the block
            # count no longer divides the axis extent
            qspec = zero_spec(pspec, mv.m.shape, mesh, enable=zero)
            qt = tuple(qspec) + (None,) * (len(mv.m.shape) - len(tuple(qspec)))
            last = qt[-1]
            s_shape = mv.m_scale.shape
            s_last = _fit_axis(last, s_shape[-1], mesh) if last else None
            sspec = P(*(qt[:-1] + (s_last,)))
            return MomentState(qspec, qspec, sspec, sspec)
        mspec = zero_spec(pspec, mv.m.shape, mesh, enable=zero)
        return MomentState(mspec, mspec)

    mv_specs = treedef.unflatten(
        [mv_spec(p, mv) for p, mv in zip(flat_p, flat_mv)])
    return {"mv": mv_specs, "step": P()}


def batch_specs(batch_shapes: dict, mesh: Mesh) -> dict:
    """Inputs: shard batch over dp axes when divisible, else sequence."""
    dps = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dps]))
    dp = dps if len(dps) > 1 else dps[0]
    out = {}
    for k, sds in batch_shapes.items():
        shape = sds.shape
        if len(shape) == 0:
            out[k] = P()
        elif shape[0] % dp_n == 0:
            out[k] = P(dp, *([None] * (len(shape) - 1)))
        elif len(shape) >= 2 and shape[1] % dp_n == 0:
            out[k] = P(None, dp, *([None] * (len(shape) - 2)))
        else:
            out[k] = P(*([None] * len(shape)))
    return out


def cache_specs_tree(caches: PyTree, mesh: Mesh) -> PyTree:
    """Decode caches: [L, B, S, H, D]-ish — shard B over dp, heads/features
    over model when divisible (best-effort, per-leaf)."""
    dps = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dps]))
    model_n = mesh.shape["model"]
    dp = dps if len(dps) > 1 else dps[0]

    def one(x):
        shape = x.shape
        spec = [None] * len(shape)
        # batch dim is axis 1 for stacked caches [L, B, ...], else 0
        bdim = 1 if len(shape) >= 2 else 0
        if len(shape) > bdim and shape[bdim] % dp_n == 0:
            spec[bdim] = dp
        # model axis: try trailing dims (heads or features), prefer axis -2
        for cand in (len(shape) - 2, len(shape) - 1):
            if cand <= bdim or cand < 0:
                continue
            if spec[cand] is None and shape[cand] % model_n == 0:
                spec[cand] = "model"
                break
        return P(*spec)

    return jax.tree.map(one, caches)


def to_named(tree_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))
