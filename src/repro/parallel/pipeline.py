"""Pipeline parallelism scheduled by the paper's polyhedral EDT machinery.

The (microbatch m, stage s) iteration space and its dependences
    (m, s) -> (m, s+1)    activation flow
    (m, s) -> (m+1, s)    stage occupancy
form a polyhedral program (``repro.core.programs.pipeline``).  We:

  1. tile the microbatch axis with the §3 *compression* method (never
     projection) to get the tile-level task graph,
  2. synthesize the wavefront schedule t(mT, s) = mT + s from the graph
     (closed form exists because the distances are uniform; the materialized
     wavefronts are asserted equal — the EDT view *is* the schedule),
  3. lower to XLA: shard_map over a 'stage' mesh axis, one `fori_loop` step
     per wavefront, `ppermute` for the (m,s)->(m,s+1) dependence.  The
     (m,s)->(m+1,s) dependence is satisfied by program order inside the
     loop — zero runtime synchronization objects (Table 2's limit point).

Training: differentiate straight through the pipelined forward — the VJP of
`ppermute` is the reverse permute, so the backward pass is the mirrored
wavefront (1F1B-family schedule) with no hand-written send/recv.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .. import compat
from ..core.edt import TiledTaskGraph, synthesize
from ..core.poly import Tiling
from ..core.programs import pipeline as pipeline_program

PyTree = Any


@dataclass
class PipelineSchedule:
    n_stages: int
    n_tiles: int           # microbatch tiles (after tiling by tile_m)
    tile_m: int
    depth: int             # wavefront count = n_tiles + n_stages - 1
    levels: list           # [[(stmt, (mT, s)), ...], ...]

    def active(self, t: int, s: int) -> bool:
        return 0 <= t - s < self.n_tiles


def build_schedule(n_microbatches: int, n_stages: int,
                   tile_m: int = 1) -> PipelineSchedule:
    """Polyhedral construction: tile, compress, synthesize wavefronts."""
    assert n_microbatches % tile_m == 0
    prog = pipeline_program()
    graph = TiledTaskGraph(prog, {"S": Tiling((tile_m, 1))})
    params = {"M": n_microbatches, "S": n_stages}
    ws = synthesize(graph, params)
    n_tiles = n_microbatches // tile_m
    # closed-form check: the wavefront index of tile (mT, s) must be mT + s
    for lvl, tasks in enumerate(ws.levels):
        for _, (mT, s) in tasks:
            assert mT + s == lvl, (mT, s, lvl)
    assert ws.depth == n_tiles + n_stages - 1
    return PipelineSchedule(n_stages, n_tiles, tile_m, ws.depth, ws.levels)


def pipelined_forward(stage_fn: Callable, stage_params: PyTree,
                      microbatches: jax.Array, schedule: PipelineSchedule,
                      mesh: Mesh, axis: str = "stage"):
    """Run the tiled pipeline under shard_map.

    stage_fn(params_one_stage, x) -> y          (same shape as x)
    stage_params: stacked [n_stages, ...]
    microbatches: [n_tiles, B_tile, ...]        (already tiled by tile_m)
    Returns [n_tiles, B_tile, ...] outputs of the final stage.
    """
    S = schedule.n_stages
    M = schedule.n_tiles
    T = schedule.depth
    perm = [(i, i + 1) for i in range(S - 1)]

    def per_stage(p_local, mbs):
        s = jax.lax.axis_index(axis)
        p1 = jax.tree.map(lambda a: a[0], p_local)   # [1,...] -> [...]
        x0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)

        def step(t, carry):
            x_buf, outs = carry
            mb_idx = jnp.clip(t, 0, M - 1)
            first_in = jax.lax.dynamic_index_in_dim(mbs, mb_idx, 0,
                                                    keepdims=False)
            x_in = jnp.where(s == 0, first_in, x_buf)
            active = jnp.logical_and(t - s >= 0, t - s < M)
            y = stage_fn(p1, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # dependence (m, s) -> (m, s+1): one wavefront step later
            x_next = jax.lax.ppermute(y, axis, perm)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            is_last = jnp.logical_and(s == S - 1, active)
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0,
                                               keepdims=False)
            new = jnp.where(is_last, y, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, new, out_idx, 0)
            return (x_next, outs)

        _, outs = jax.lax.fori_loop(0, T, step, (x0, outs0))
        # only the last stage holds real outputs; broadcast them
        outs = jnp.where(s == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    nd = microbatches.ndim
    return compat.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P(*([None] * nd))),
        out_specs=P(*([None] * nd)),
    )(stage_params, microbatches)


def sequential_reference(stage_fn: Callable, stage_params: PyTree,
                         microbatches: jax.Array) -> jax.Array:
    """Oracle: apply all stages to every microbatch sequentially."""

    def apply_all(x):
        def body(h, p):
            return stage_fn(p, h), None
        h, _ = jax.lax.scan(body, x, stage_params)
        return h

    return (jax.vmap(apply_all)(microbatches) if False
            else jnp.stack([apply_all(mb) for mb in microbatches]))


def make_pipeline_loss(stage_fn, schedule, mesh, axis="stage"):
    """Training through the pipeline: grad flows back through ppermute
    (reverse wavefront = the backward pipeline, synthesized for free)."""

    def loss(stage_params, microbatches, targets):
        outs = pipelined_forward(stage_fn, stage_params, microbatches,
                                 schedule, mesh, axis)
        return jnp.mean((outs - targets) ** 2)

    return loss
