"""Data pipeline: sharded synthetic token streams with EDT-driven prefetch.

Production stance: each host produces only its shard of the global batch
(``host_slice``); batches are staged ahead of the training step by the
autodec runtime (the prefetch task for step t+k depends on the consumption
of step t — a counted dependence, paper §2.2.4), so input pipeline stalls
surface as EDT-queue depth, not device idle time.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_seq: int = 0      # >0: also emit stub modality embeddings
    d_model: int = 0


class SyntheticLM:
    """Deterministic synthetic LM stream (zipfian-ish token marginals).

    Deterministic in (seed, step, host) so checkpoint-restart resumes the
    exact stream — a fault-tolerance requirement, not a convenience.
    """

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id]))
        # zipf-flavored marginals, cheap to generate
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = (cfg.vocab * u ** 3).astype(np.int32) % cfg.vocab
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if cfg.frontend_seq:
            emb = rng.standard_normal(
                (self.local_batch, cfg.frontend_seq, cfg.d_model),
                dtype=np.float32) * 0.02
            out["extra_embeds"] = jnp.asarray(emb, jnp.bfloat16)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchPipeline:
    """EDT-style prefetch: a bounded queue fed by autodec-scheduled tasks."""

    def __init__(self, source: SyntheticLM, depth: int = 2,
                 start_step: int = 0):
        from ..core.edt.threaded import ThreadedAutodec
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.depth = depth
        self._next_to_produce = start_step
        self._lock = threading.Lock()
        # each produce-task has exactly one input dependence: a free queue
        # slot; consuming a batch autodecs the producer of step+depth.
        self.rt = ThreadedAutodec(
            pred_count=lambda step: 1,
            successors=lambda step: [],
            body=self._produce,
            workers=1,
        )
        for s in range(start_step, start_step + depth):
            self.rt.autodec(s)   # initial slots are free

    def _produce(self, step: int) -> None:
        self.q.put((step, self.source.batch_at(step)))

    def get(self) -> tuple[int, dict]:
        step, batch = self.q.get()
        self.rt.autodec(step + self.depth)   # freed slot -> schedule producer
        return step, batch

    def close(self):
        self.rt.shutdown()
