"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode automatically; on
TPU they compile to Mosaic.  Layout adapters live here so model code can stay
in its natural [B, S, H, D] layout.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_hm
from .ssd import ssd_pallas
from .wkv6 import wkv6_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    bq: int = 128, bk: int = 128):
    """q [B,Sq,H,D], k/v [B,Skv,Hkv,D] -> [B,Sq,H,D] (GQA-aware)."""
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_hm(qh, kh, vh, causal=causal, bq=bq, bk=bk,
                             interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def wkv6(r, k, v, w, u, init_state=None, *, chunk: int = 64):
    """RWKV6 recurrence: r,k,v,w [B,S,H,D], u [H,D] -> (out, state)."""
    return wkv6_pallas(r, k, v, w, u, init_state, chunk=chunk,
                       interpret=_interpret())


def ssd(x, dt, A, Bm, Cm, init_state=None, *, chunk: int = 128):
    """Mamba2 SSD: x [B,S,H,P], dt [B,S,H], A [H], Bm/Cm [B,S,N]."""
    return ssd_pallas(x, dt, A, Bm, Cm, init_state, chunk=chunk,
                      interpret=_interpret())
