"""Stencil tile bodies: the compute the EDT graphs of ``core.programs``
synchronize.

The polyhedral programs are written in *time-skewed* coordinates (x = i +
t) so orthogonal tiling is legal; the numerics live in unskewed "site"
space ``s = x - t``.  A :class:`StencilSpec` names that semantics once:

* task point ``(t, x...)`` computes the value ``v_t[s]`` of its site,
* a tap ``(dt, offsets, weight)`` reads ``v_{t-dt}[s + offsets]``,
* reads outside ``[0, N)^d`` contribute zero (a Dirichlet-0 halo),
* ``v_{-1}`` is the initial grid; the solve's answer is ``v_{T-1}``.

Because every tap has ``dt`` in {0, 1}, two buffers suffice: ``v_t`` lives
in parity buffer ``t & 1`` (so the initial grid seeds buffer 1).  Taps
with ``dt == 0`` read sites the *same* time step already wrote —
Gauss-Seidel — which is why :class:`StencilSpec.seq_space` marks spatial
dims that must run sequentially inside a tile; pure Jacobi bodies
vectorize over all spatial dims.

Three implementations of the same spec live here, used as ladders of
trust by ``tests/test_fused_exec.py``:

* :func:`reference_solve` — plain NumPy, time-major (the ground truth),
* :func:`handwritten_solve` — the hand-tuned jax baseline the fused
  executor is benchmarked against: one ``lax.fori_loop`` over time with
  pad+slice taps (Jacobi) or a ``lax.scan`` carry (Seidel), no task
  graph, no counters — the best case for a fixed-shape runtime,
* the fused device body itself (``core.edt.fused``), which executes the
  identical taps level by level inside the counted-sync sweep.

This module stays import-light (no jax at module scope): the fused
executor imports it from ``repro.core.edt``, which process-pool workers
load jax-free.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StencilSpec:
    """One stencil body in unskewed site space.

    ``taps`` is a tuple of ``(dt, offsets, weight)`` with ``dt`` in
    {0, 1}; ``seq_space[k]`` marks spatial dim ``k`` as sequential inside
    a tile (required exactly when some tap has ``dt == 0``, whose offsets
    must then be lexicographically negative).  ``time_param`` /
    ``size_param`` name the polyhedral program's symbolic sizes.
    """

    name: str
    space: int
    taps: tuple
    seq_space: tuple
    time_param: str = "T"
    size_param: str = "N"

    @property
    def sequential(self) -> bool:
        return any(self.seq_space)

    def shape(self, extent: int) -> tuple:
        return (extent,) * self.space


def _box_taps(space: int) -> tuple:
    offs = list(itertools.product((-1, 0, 1), repeat=space))
    w = 1.0 / len(offs)
    return tuple((1, off, w) for off in offs)


#: Specs for the stencil programs of ``repro.core.programs`` (keyed by
#: the PROGRAMS name).  The site offsets are the skewed dependence
#: offsets shifted by the time skew: x_t - x_s in [0, 2] becomes
#: s-offsets {-1, 0, 1} at dt = 1.
SPECS = {
    "stencil1d": StencilSpec("stencil1d", 1, _box_taps(1), (False,)),
    "jacobi2d": StencilSpec("jacobi2d", 2, _box_taps(2), (False, False)),
    "heat3d": StencilSpec("heat3d", 3, _box_taps(3), (False,) * 3),
    # Gauss-Seidel: half the value from this step's left neighbor (the
    # skewed "sweep" dependence), half from last step's right neighbor
    # (the skewed "carry") — the x dim is sequential.
    "seidel1d": StencilSpec("seidel1d", 1,
                            ((0, (-1,), 0.5), (1, (1,), 0.5)), (True,)),
}


def default_state(spec: StencilSpec, extent: int, dtype=np.float32):
    """A deterministic, non-smooth initial grid (linear fields would let
    indexing bugs cancel under averaging stencils)."""
    size = extent ** spec.space
    v = (np.arange(size, dtype=np.int64) * 2654435761) % 1021
    return (v.astype(np.float64) / 1021.0).astype(dtype).reshape(
        spec.shape(extent))


def _shift(a: "np.ndarray", off) -> "np.ndarray":
    """``out[s] = a[s + off]`` with zeros shifted in at the boundary."""
    out = np.zeros_like(a)
    dst, src = [], []
    for k, o in enumerate(off):
        n = a.shape[k]
        lo, hi = max(0, -o), n - max(0, o)
        dst.append(slice(lo, hi))
        src.append(slice(lo + o, hi + o))
    out[tuple(dst)] = a[tuple(src)]
    return out


def reference_solve(spec: StencilSpec, state: "np.ndarray",
                    steps: int) -> "np.ndarray":
    """Ground truth: time-major NumPy execution of the spec.

    Jacobi-style specs (all taps at ``dt == 1``) run as vectorized
    shifts; Gauss-Seidel specs run the honest ordered scalar loop (site
    lex order — the order the skewed schedule implies)."""
    prev = np.array(state)
    ty = prev.dtype.type
    for _ in range(steps):
        if not spec.sequential:
            acc = None
            for _, off, w in spec.taps:
                term = ty(w) * _shift(prev, off)
                acc = term if acc is None else acc + term
            prev = acc
            continue
        cur = np.zeros_like(prev)
        for idx in np.ndindex(prev.shape):
            acc = ty(0)
            for dt, off, w in spec.taps:
                j = tuple(i + o for i, o in zip(idx, off))
                if all(0 <= jj < n for jj, n in zip(j, prev.shape)):
                    acc = acc + ty(w) * (cur[j] if dt == 0 else prev[j])
            cur[idx] = acc
        prev = cur
    return prev


def handwritten_solve(spec: StencilSpec, state: "np.ndarray",
                      steps: int) -> "np.ndarray":
    """The hand-tuned jax baseline: the same solve with no task graph.

    Dense Jacobi bodies are one ``lax.fori_loop`` over time whose body is
    a pad + 3^d static slices; the Seidel recurrence is a ``lax.scan``
    carry inside the time loop.  This is what a performance engineer
    would write given the *whole* problem up front — the fused EDT sweep
    is priced against it in ``benchmarks/bench_fused.py``.
    """
    import jax.numpy as jnp
    from jax import lax

    n = state.shape[0]
    u0 = jnp.asarray(state)

    if not spec.sequential:
        def step(_, u):
            p = jnp.pad(u, 1)
            acc = None
            for _, off, w in spec.taps:
                start = tuple(1 + o for o in off)
                term = w * lax.slice(p, start, tuple(s + n for s in start))
                acc = term if acc is None else acc + term
            return acc

        return np.asarray(lax.fori_loop(0, steps, step, u0))

    if spec.space != 1:
        raise NotImplementedError(
            "handwritten sequential baseline is 1-D only")
    seq = [(off, w) for dt, off, w in spec.taps if dt == 0]
    if seq != [((-1,), seq[0][1])]:
        raise NotImplementedError(
            "sequential baseline expects a single dt=0 tap at offset -1")
    w0 = seq[0][1]

    def step(_, u):
        p = jnp.pad(u, 1)
        pre = None
        for dt, off, w in spec.taps:
            if dt == 0:
                continue
            term = w * lax.slice(p, (1 + off[0],), (1 + off[0] + n,))
            pre = term if pre is None else pre + term

        def carry(c, b):
            v = w0 * c + b
            return v, v

        _, out = lax.scan(carry, jnp.zeros((), u.dtype), pre)
        return out

    return np.asarray(lax.fori_loop(0, steps, step, u0))
