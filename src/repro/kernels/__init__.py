"""Pallas TPU kernels (validated on CPU in interpret mode vs ref.py oracles).

Submodules load lazily (PEP 562): ``stencils`` is imported by the fused
device executor from process-pool workers that must stay jax-free, while
``ops``/``flash_attention``/``ssd``/``wkv6`` pull in jax + pallas — an
eager ``from . import ops`` here would defeat the deferred-import
discipline ``core.edt.device`` keeps.
"""
import importlib

_SUBMODULES = ("flash_attention", "ops", "ref", "ssd", "stencils", "wkv6")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
