"""Pallas TPU kernels (validated on CPU in interpret mode vs ref.py oracles)."""
from . import ops, ref

__all__ = ["ops", "ref"]
