"""Mamba2 SSD (state-space dual) chunked-scan Pallas TPU kernel.

One grid cell owns a (batch, head) pair; the chunk axis is the innermost
*sequential* grid dimension, so the [P, N] recurrent state stays resident in
VMEM scratch across chunks (the inter-tile dependence of the EDT view is a
VMEM-resident carry, not an HBM round trip).

Within a chunk of length C the kernel evaluates the quadratic "dual" form:
    y = ((C_mat @ B_mat^T) ⊙ decay) @ (dt ⊙ x)  +  decay_in ⊙ (C_mat @ state)
which is two (C×N)(N×C) / (C×C)(C×P) MXU matmuls instead of C rank-1 updates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..compat import pallas, pallas_tpu, tpu_compiler_params

# resolved at import so a pallas-less jax fails here, not mid-call; the
# version shim (and its test monkeypatch point) lives in compat
pl = pallas(required=True)
pltpu = pallas_tpu(required=True)


def _kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, s0_ref,
            y_ref, sf_ref, state_ref, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    A = A_ref[0].astype(jnp.float32)                       # scalar decay rate
    dt = dt_ref[0, :, 0].astype(jnp.float32)               # [C]
    x = x_ref[0, :, 0, :].astype(jnp.float32)              # [C, P]
    Bm = b_ref[0].astype(jnp.float32)                      # [C, N]
    Cm = c_ref[0].astype(jnp.float32)                      # [C, N]

    dA = dt * A                                            # [C] (<= 0)
    cums = jnp.cumsum(dA)                                  # [C]
    seg = jnp.exp(cums)                                    # decay from chunk start

    # inter-chunk: y_state[t] = seg[t] * C[t] . state
    y_state = seg[:, None] * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [C, P]

    # intra-chunk quadratic form
    rel = cums[:, None] - cums[None, :]                    # [C, C]
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(iota_r >= iota_c, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y_intra = jax.lax.dot_general(scores, dt[:, None] * x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y_state + y_intra).astype(y_ref.dtype)

    # state update: state = exp(cums[-1]) * state + sum_t w_t dt_t x_t B_t^T
    w = jnp.exp(cums[-1] - cums)                           # decay t..chunk end
    xw = (dt * w)[:, None] * x                             # [C, P]
    state_ref[...] = jnp.exp(cums[-1]) * state_ref[...] + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [P, N]

    @pl.when(ic == nc - 1)
    def _fin():
        sf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, Bm, Cm, init_state=None, *, chunk: int = 128,
               interpret: bool = False):
    """x [B,S,H,P], dt [B,S,H], A [H], Bm/Cm [B,S,N] -> (y, final_state)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, sf = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, init_state)
    return y, sf
