"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are deliberately naive/direct implementations — the ground truth the
kernels are validated against (interpret mode on CPU, shape/dtype sweeps).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q [B,Sq,H,D], k/v [B,Skv,Hkv,D] (GQA by grouping). Direct softmax."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[-1]
                                                ).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, init_state=None):
    """RWKV6 recurrence, step by step (the definition).

    r,k,v,w: [B,S,H,D]; u: [H,D]; state [B,H,D,D] (key-major outer products).
      out[t] = r_t . (state + u * (k_t ⊗ v_t));  state = w_t*state + k_t ⊗ v_t
    Returns (out [B,S,H,D], final_state).
    """
    B, S, H, D = r.shape
    state = (init_state if init_state is not None
             else jnp.zeros((B, H, D, D), jnp.float32))
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    outs = []
    for t in range(S):
        kv = kf[:, t, :, :, None] * vf[:, t, :, None, :]
        outs.append(jnp.einsum("bhd,bhde->bhe", rf[:, t],
                               state + u[None, :, :, None] * kv))
        state = wf[:, t][..., None] * state + kv
    return jnp.stack(outs, axis=1).astype(r.dtype), state


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """Mamba2 SSD recurrence, step by step (the definition).

    x [B,S,H,P], dt [B,S,H] (>=0), A [H] (negative), Bm/Cm [B,S,N].
      state = exp(dt_t A) * state + dt_t * (x_t ⊗ B_t);   y_t = C_t . state
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    state = (init_state if init_state is not None
             else jnp.zeros((B, H, P, N), jnp.float32))
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    ys = []
    for t in range(S):
        decay = jnp.exp(dtf[:, t] * A[None, :])             # [B,H]
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cf[:, t], state))
    return jnp.stack(ys, axis=1).astype(x.dtype), state
