"""RWKV6 (WKV) recurrence Pallas TPU kernel.

The recurrence
    out[t] = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T
carries a [D, D] state per (batch, head).  TPU mapping:

  * grid = (B, H, S/C): chunks of the time axis are the innermost
    *sequential* axis; the state matrix lives in VMEM scratch across chunks
    (HBM traffic is O(S·D) for the streams, state never leaves VMEM);
  * within a chunk, a fori_loop of rank-1 updates runs on the VPU; D=64
    lanes fit one vreg row, so the [D, D] outer product is a single
    broadcast-multiply.

This is the paper-style "task body" for the attention-free arch: sequence
chunks are the EDT tiles, the state hand-off is the inter-tile dependence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..compat import pallas, pallas_tpu, tpu_compiler_params

# resolved at import so a pallas-less jax fails here, not mid-call; the
# version shim (and its test monkeypatch point) lives in compat
pl = pallas(required=True)
pltpu = pallas_tpu(required=True)


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            o_ref, sf_ref, state_ref, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                 # [D]

    def step(t, _):
        rt = r_ref[0, t, 0, :].astype(jnp.float32)   # [D]
        kt = k_ref[0, t, 0, :].astype(jnp.float32)
        vt = v_ref[0, t, 0, :].astype(jnp.float32)
        wt = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]               # [D, D]
        out = jnp.einsum("d,de->e", rt, state_ref[...] + u[:, None] * kv)
        o_ref[0, t, 0, :] = out.astype(o_ref.dtype)
        state_ref[...] = wt[:, None] * state_ref[...] + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(ic == nc - 1)
    def _fin():
        sf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, init_state=None, *, chunk: int = 64,
                interpret: bool = False):
    """r,k,v,w: [B,S,H,D]; u: [H,D]; init_state [B,H,D,D] (f32) optional."""
    B, S, H, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, D, D), jnp.float32)

    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    out, sf = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, init_state)
    return out, sf
