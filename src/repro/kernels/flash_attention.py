"""Flash attention Pallas TPU kernel (causal, GQA-aware).

TPU adaptation notes (vs the CUDA original):
  * tiles are MXU-aligned: BQ × D and BK × D with D padded to 128 lanes;
  * the KV dimension is the *innermost, sequential* grid axis so the f32
    accumulators (m, l, acc) live in VMEM scratch across KV steps — the TPU
    equivalent of a CUDA thread-block's shared-memory accumulators;
  * causal blocks above the diagonal are skipped with ``pl.when`` (the grid
    still visits them; skipping the compute keeps the MXU idle time minimal).

Layouts: q [B, H, Sq, D], k/v [B, Hkv, Skv, D] — head-major so a block is a
contiguous (BQ, D) tile per (batch, head).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from ..compat import pallas, pallas_tpu, tpu_compiler_params

# resolved at import so a pallas-less jax fails here, not mid-call; the
# version shim (and its test monkeypatch point) lives in compat
pl = pallas(required=True)
pltpu = pallas_tpu(required=True)

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # block-level skip: no keys in this block can be visible
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / lsum[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_hm(q, k, v, *, causal: bool = True, bq: int = 128,
                       bk: int = 128, interpret: bool = False):
    """Head-major flash attention: q [B,H,Sq,D], k/v [B,Hkv,Skv,D]."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(D)

    grid = (B, H, nq, nk)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
