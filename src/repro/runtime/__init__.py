"""Fault-tolerant training runtime (host-side orchestration via autodec EDTs)."""
from .driver import DriverConfig, TrainDriver

__all__ = ["TrainDriver", "DriverConfig"]
