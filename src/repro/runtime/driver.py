"""Fault-tolerant training driver.

Responsibilities (each one an EDT on the host autodec runtime — the paper's
proposed synchronization model orchestrates the *cluster-level* events that
XLA cannot see):

  * data prefetch      — producer tasks gated by queue-slot dependences;
  * async checkpoint   — save tasks chained by counted dependences
                         (step-atomic manifests; crash => clean restart);
  * straggler backup   — for host-side work items (eval, data shard fetch),
                         a backup task is autodec'd after a deadline; first
                         completion wins, exactly-once by the atomic counter
                         (the paper's Fig-1 race, resolved by design);
  * failure recovery   — any step failure (device loss is injected in tests)
                         restores the latest checkpoint and replays the
                         deterministic data stream;
  * elastic restart    — ``restore`` reshards onto whatever mesh exists now.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional


from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..core.edt.threaded import ThreadedAutodec
from ..data import DataConfig, PrefetchPipeline, SyntheticLM


@dataclass
class DriverConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    prefetch_depth: int = 2
    max_restarts: int = 3
    straggler_deadline_s: float = 5.0


@dataclass
class StepResult:
    step: int
    loss: float
    restarts: int


class TrainDriver:
    """Run ``train_step`` with prefetch, async checkpoint and restart."""

    def __init__(self, cfg: DriverConfig, data_cfg: DataConfig,
                 train_step: Callable, init_fn: Callable[[], tuple],
                 fault_hook: Optional[Callable[[int], None]] = None):
        """init_fn() -> (params, opt_state); train_step(params, opt, batch)
        -> (params, opt, loss).  fault_hook(step) may raise to inject a
        failure (tests)."""
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.train_step = train_step
        self.init_fn = init_fn
        self.fault_hook = fault_hook
        self.history: list[StepResult] = []
        self.restarts = 0

    # ------------------------------------------------------------ recovery
    def _restore_or_init(self):
        params, opt_state = self.init_fn()
        step0 = 0
        last = latest_step(self.cfg.ckpt_dir)
        if last is not None:
            state = restore(self.cfg.ckpt_dir, last,
                            {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            step0 = last + 1
        return params, opt_state, step0

    # ------------------------------------------------------------- run loop
    def run(self) -> list[StepResult]:
        cfg = self.cfg
        attempt = 0
        while True:
            try:
                self._run_once()
                return self.history
            except Exception:
                attempt += 1
                self.restarts += 1
                if attempt > cfg.max_restarts:
                    raise
                # fall through: restart restores from the latest checkpoint

    def _run_once(self) -> None:
        cfg = self.cfg
        params, opt_state, step0 = self._restore_or_init()
        source = SyntheticLM(self.data_cfg)
        pipe = PrefetchPipeline(source, depth=cfg.prefetch_depth,
                                start_step=step0)
        ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.ckpt_keep)
        try:
            for step in range(step0, cfg.total_steps):
                got_step, batch = pipe.get()
                assert got_step == step, (got_step, step)
                if self.fault_hook is not None:
                    self.fault_hook(step)
                params, opt_state, loss = self.train_step(
                    params, opt_state, batch)
                self.history.append(
                    StepResult(step, float(loss), self.restarts))
                if (step + 1) % cfg.ckpt_every == 0 or step == cfg.total_steps - 1:
                    ckpt.submit(step, {"params": params, "opt": opt_state})
            ok = ckpt.wait(timeout=300)
            assert ok, "checkpointer did not quiesce"
        finally:
            pipe.close()
            ckpt.close()


# ---------------------------------------------------------------- stragglers
def run_with_backup(work: Callable[[], Any], deadline_s: float,
                    backup: Optional[Callable[[], Any]] = None) -> Any:
    """First-completion-wins execution of a host-side work item.

    Primary and (deadline-delayed) backup tasks share one autodec counter;
    whichever finishes first publishes the result — the other's completion
    finds the 'scheduled' flag set and is dropped.  This is the paper's
    atomic-creation mechanism reused for straggler mitigation.
    """
    import threading

    result: dict[str, Any] = {}
    done = threading.Event()
    publish_lock = threading.Lock()

    def publisher(key):
        out = (work if key == "primary" else (backup or work))()
        with publish_lock:
            if "value" not in result:   # first completion wins
                result["value"] = out
                result["by"] = key
        done.set()

    rt = ThreadedAutodec(pred_count=lambda k: 1,
                         successors=lambda k: [],
                         body=publisher, workers=2)
    rt.autodec("primary")

    def arm_backup():
        if not done.wait(deadline_s):
            rt.autodec("backup")

    t = threading.Thread(target=arm_backup, daemon=True)
    t.start()
    done.wait()
    rt.wait(timeout=60)
    rt.shutdown()
    return result["value"], result["by"]
