"""Sharded checkpointing with atomic manifests, async save, elastic restore.

Layout:
    <dir>/step_<N>/
        manifest.json          (written LAST -> step-atomic commit)
        arr_<i>.npy            one file per pytree leaf (host shard)

Fault-tolerance contract:
  * a checkpoint is valid iff its manifest exists (crash mid-save leaves no
    manifest -> restart ignores the partial step);
  * ``latest_step`` scans for the newest valid manifest;
  * saves run asynchronously on the autodec runtime: the save task for step
    t depends on (a) step t's arrays being snapshotted and (b) the save of
    step t-1 having completed (a counted dependence chain), so saves never
    reorder and never block the training loop;
  * ``restore`` accepts a target pytree with *different sharding* (elastic
    restart on a smaller/larger mesh): arrays are loaded full and resharded
    via device_put.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_sync(ckpt_dir: str | Path, step: int, tree: PyTree) -> Path:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i}.npy", arr)
        metas.append({"i": i, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)})
    manifest = {"step": step, "n_arrays": len(leaves), "arrays": metas,
                "treedef": str(treedef), "time": time.time()}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)   # manifest inside; rename is the atomic commit
    return d


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for sub in d.glob("step_*"):
        if (sub / "manifest.json").exists():
            try:
                steps.append(int(sub.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target: PyTree) -> PyTree:
    """Load into the structure (and shardings) of ``target``.

    target leaves may be ShapeDtypeStructs with .sharding (elastic restore)
    or concrete arrays (their sharding is reused).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(target)
    assert manifest["n_arrays"] == len(leaves), (
        f"checkpoint has {manifest['n_arrays']} leaves, target {len(leaves)}")
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.load(d / f"arr_{i}.npy")
        want_shape = tuple(leaf.shape)
        assert tuple(arr.shape) == want_shape, (i, arr.shape, want_shape)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and not isinstance(
                sharding, jax.sharding.SingleDeviceSharding):
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Autodec-scheduled async saves with a strict completion chain."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        from ..core.edt.threaded import ThreadedAutodec
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._pending: dict[int, PyTree] = {}
        self._lock = threading.Lock()
        self.saved_steps: list[int] = []
        self._seq: list[int] = []      # submission order
        self._done: set[int] = set()
        self.rt = ThreadedAutodec(
            pred_count=lambda step: 2,   # snapshot ready + previous save done
            successors=self._succ,
            body=self._save,
            workers=1,
        )

    def _succ(self, step: int):
        # called after _save(step) returned; signal the next submitted save
        with self._lock:
            self._done.add(step)
            idx = self._seq.index(step)
            return [self._seq[idx + 1]] if idx + 1 < len(self._seq) else []

    def _save(self, step: int) -> None:
        with self._lock:
            tree = self._pending.pop(step)
        save_sync(self.dir, step, tree)
        self.saved_steps.append(step)
        self._gc()

    def _gc(self):
        steps = sorted(self.saved_steps)
        for s in steps[:-self.keep]:
            p = self.dir / f"step_{s:08d}"
            if p.exists():
                shutil.rmtree(p)
            self.saved_steps.remove(s)

    def submit(self, step: int, tree: PyTree) -> None:
        """Snapshot (device_get) and schedule the save."""
        snap = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._pending[step] = snap
            prev = self._seq[-1] if self._seq else None
            self._seq.append(step)
            prev_done = prev is None or prev in self._done
        self.rt.autodec(step)          # dependence 1: snapshot ready
        if prev_done:
            # predecessor save already finished (or none): signal now; the
            # completion-side signal may double-fire, which autodec absorbs
            # (exactly-once scheduling is guaranteed by the scheduled-set).
            self.rt.autodec(step)
        return None

    def wait(self, timeout: float = 120) -> bool:
        return self.rt.wait(timeout)

    def close(self):
        self.wait()
        self.rt.shutdown()
