"""Optimizers: AdamW with f32 or 8-bit (block-quantized) moment states.

The 8-bit option is a distributed-optimization feature: at 671B params the
f32 m/v states are 5.4 TB; block-wise int8 with per-block scales cuts them
~3.9x, which together with ZeRO-style sharding is what fits the v5e 16 GB
HBM budget (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: int = 32          # 32 (f32 moments) or 8 (block-int8)
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ----------------------------------------------------- 8-bit moment encoding
# Shape-preserving block quantization: int8 with per-(last-axis-block) f32
# scales.  Both the int8 moments and the scales keep the PARAM's shape family
# (q: p.shape; scales: p.shape[:-1] + (last/BLOCK,)), so the optimizer state
# inherits the parameter sharding leaf-for-leaf — no flattening, no resharding
# collectives in the update (critical at 671B: a flatten would force XLA to
# materialize full moment tensors per device).

def _q8_last(x: jax.Array) -> int:
    last = x.shape[-1] if x.ndim else 1
    return BLOCK if last % BLOCK == 0 else last


def _q8_encode(x: jax.Array):
    blk = _q8_last(x)
    shape = x.shape
    nb = shape[-1] // blk
    b = x.reshape(shape[:-1] + (nb, blk))
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale[..., 0].astype(jnp.float32)


def _q8_decode(q: jax.Array, scale: jax.Array):
    blk = _q8_last(q)
    shape = q.shape
    nb = shape[-1] // blk
    b = q.reshape(shape[:-1] + (nb, blk)).astype(jnp.float32)
    return (b * scale[..., None]).reshape(shape)


_Q8_MIN_SIZE = 65536  # small leaves (norm scales, biases) stay f32


class MomentState(NamedTuple):
    m: Any
    v: Any
    m_scale: Optional[Any] = None
    v_scale: Optional[Any] = None


def init_state(cfg: AdamWConfig, params: PyTree):
    def one(p):
        if cfg.state_bits == 8 and p.size >= _Q8_MIN_SIZE and p.ndim >= 2:
            blk = _q8_last(p)
            sshape = p.shape[:-1] + (p.shape[-1] // blk,)
            return MomentState(jnp.zeros(p.shape, jnp.int8),
                               jnp.zeros(p.shape, jnp.int8),
                               jnp.zeros(sshape, jnp.float32),
                               jnp.zeros(sshape, jnp.float32))
        return MomentState(jnp.zeros(p.shape, jnp.float32),
                           jnp.zeros(p.shape, jnp.float32))
    return {"mv": jax.tree.map(one, params,
                               is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, mv: MomentState):
        g = g.astype(jnp.float32) * clip
        quantized = mv.m_scale is not None
        if quantized:
            m = _q8_decode(mv.m, mv.m_scale)
            v = _q8_decode(mv.v, mv.v_scale)
        else:
            m, v = mv.m, mv.v
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = (p.astype(jnp.float32) - lr * (upd + decay * p.astype(jnp.float32))
                ).astype(p.dtype)
        if quantized:
            qm, sm = _q8_encode(m)
            qv, sv = _q8_encode(v)
            return newp, MomentState(qm, qv, sm, sv)
        return newp, MomentState(m, v)

    def one_scanned(p, g, mv: MomentState):
        """§Perf: update huge stacked leaves one slice at a time so only a
        single layer's f32 moments are ever live (671B-scale: the whole-leaf
        decode would transiently hold ~12 GB/dev per expert tensor)."""
        def body(_, slc):
            pi, gi, mvi = slc
            npi, nmvi = one(pi, gi, mvi)
            return None, (npi, nmvi)
        _, (newp, newmv) = jax.lax.scan(body, None, (p, g, mv))
        return newp, newmv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mv = treedef.flatten_up_to(state["mv"])
    out = []
    for p, g, mv in zip(flat_p, flat_g, flat_mv):
        big = p.ndim >= 3 and p.size >= (1 << 26) and p.shape[0] > 1
        out.append((one_scanned if big else one)(p, g, mv))
    new_params = treedef.unflatten([o[0] for o in out])
    new_mv = treedef.unflatten([o[1] for o in out])
    return new_params, {"mv": new_mv, "step": step}
