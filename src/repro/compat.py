"""Version-compat shims for the installed jax.

The kernels/parallel layers were written against newer jax spellings
(``jax.shard_map`` with ``check_vma``, ``pltpu.CompilerParams``); older
releases ship ``jax.experimental.shard_map.shard_map`` with ``check_rep``
and ``pltpu.TPUCompilerParams``.  These helpers resolve whichever the
installed jax provides, so the same source runs on both sides of the
renames without pinning a jax version (nothing may be pip-installed in the
target container).
"""
from __future__ import annotations

import inspect

import jax


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams`` (renamed)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def pallas(required: bool = False):
    """The ``jax.experimental.pallas`` module, or ``None`` when this jax
    build ships without it (minimal CPU wheels, very old releases).

    Callers that can fall back to plain XLA ops should do so when this
    returns ``None`` instead of wrapping their own try/except — keeping the
    capability check here means one place to fix when the import path moves
    (and tests can monkeypatch this function to simulate a pallas-less jax).
    ``required=True`` raises instead of returning ``None``, for modules
    whose whole point is the pallas kernel (``repro.kernels``).
    """
    try:
        from jax.experimental import pallas as pl
    except ImportError:
        if required:
            raise RuntimeError(
                "this jax build has no pallas module; the XLA fallbacks in "
                "repro.kernels.ref / core.edt.device cover the same ops")
        return None
    return pl


def has_pallas() -> bool:
    """True when :func:`pallas` resolves — cheap capability probe."""
    return pallas() is not None


def pallas_tpu(required: bool = False):
    """The ``jax.experimental.pallas.tpu`` module (``pltpu``), or ``None``.

    Split from :func:`pallas` because CPU-only wheels have shipped the core
    pallas package without its TPU backend."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:
        if required:
            raise RuntimeError(
                "this jax build has no pallas TPU backend (pltpu)")
        return None
    return pltpu


def enable_x64():
    """Context manager enabling 64-bit jax types for its extent.

    ``jax.experimental.enable_x64`` where available (it scopes the change
    per-thread instead of flipping global config); otherwise a fallback
    that toggles ``jax_enable_x64`` and restores it.  Used by the fused
    executor's float64 paths so test suites never leak x64 state.
    """
    try:
        from jax.experimental import enable_x64 as ctx
    except ImportError:
        import contextlib

        @contextlib.contextmanager
        def ctx():
            old = jax.config.jax_enable_x64
            jax.config.update("jax_enable_x64", True)
            try:
                yield
            finally:
                jax.config.update("jax_enable_x64", old)

    return ctx()


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map(check_vma=)`` / experimental ``shard_map(check_rep=)``."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    params = inspect.signature(sm).parameters
    if "check_vma" in params:
        kw["check_vma"] = check
    elif "check_rep" in params:
        kw["check_rep"] = check
    return sm(f, **kw)
