"""Version-compat shims for the installed jax.

The kernels/parallel layers were written against newer jax spellings
(``jax.shard_map`` with ``check_vma``, ``pltpu.CompilerParams``); older
releases ship ``jax.experimental.shard_map.shard_map`` with ``check_rep``
and ``pltpu.TPUCompilerParams``.  These helpers resolve whichever the
installed jax provides, so the same source runs on both sides of the
renames without pinning a jax version (nothing may be pip-installed in the
target container).
"""
from __future__ import annotations

import inspect

import jax


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams`` (renamed)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def pallas():
    """The ``jax.experimental.pallas`` module, or ``None`` when this jax
    build ships without it (minimal CPU wheels, very old releases).

    Callers that can fall back to plain XLA ops should do so when this
    returns ``None`` instead of wrapping their own try/except — keeping the
    capability check here means one place to fix when the import path moves
    (and tests can monkeypatch this function to simulate a pallas-less jax).
    """
    try:
        from jax.experimental import pallas as pl
    except ImportError:
        return None
    return pl


def has_pallas() -> bool:
    """True when :func:`pallas` resolves — cheap capability probe."""
    return pallas() is not None


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map(check_vma=)`` / experimental ``shard_map(check_rep=)``."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    params = inspect.signature(sm).parameters
    if "check_vma" in params:
        kw["check_vma"] = check
    elif "check_rep" in params:
        kw["check_rep"] = check
    return sm(f, **kw)
