"""whisper-tiny [audio]: enc-dec backbone; conv frontend is a STUB
(input_specs provides 1500 precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    mlp="gelu",
    encdec=True, n_encoder_layers=4,
    frontend="frame_stub", frontend_seq=1536,  # 1500 mel frames padded to the 512-tile boundary
)
