"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81 layers, d_model=3584, ssm_state=64; the single shared attention+MLP block
is applied every 6 layers (weights shared across applications).
At long context the shared attention uses a 4096 sliding window (deviation
recorded in DESIGN.md; SSM layers carry the long-range state).
[arXiv:2411.15242; unverified]
"""
from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, d_conv=4, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6,
    sliding_window=4096,
)
