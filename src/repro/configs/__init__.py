"""Assigned architectures (exact configs) + input shapes + ShapeDtypeStruct specs.

Each module defines CONFIG; the registry maps ``--arch <id>`` names to them.
``input_specs(cfg, shape)`` builds allocation-free ShapeDtypeStruct stand-ins
for the dry-run; ``applicable(cfg, shape)`` encodes the skip rules
(long_500k needs a sub-quadratic path; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from . import (deepseek_v3_671b, granite_moe_1b_a400m, internvl2_26b,
               llama3_2_1b, qwen2_5_3b, rwkv6_1_6b, smollm_360m,
               starcoder2_3b, whisper_tiny, zamba2_7b)

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_5_3b, smollm_360m, llama3_2_1b, starcoder2_3b, zamba2_7b,
              deepseek_v3_671b, granite_moe_1b_a400m, rwkv6_1_6b,
              internvl2_26b, whisper_tiny)
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    s = SHAPES[shape]
    if s.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: O(S^2) attention at 500k "
                       "is intractable; skip per assignment (see DESIGN.md)")
    return True, ""


def _text_len(cfg: ArchConfig, seq: int) -> int:
    if cfg.frontend != "none":
        return max(1, seq - cfg.frontend_seq)
    return seq


def input_specs(cfg: ArchConfig, shape: str, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens, labels [, extra_embeds]}
    prefill: {tokens [, extra_embeds]}
    decode:  {tokens1, pos}  (+ caches built separately via cache_specs)
    """
    s = SHAPES[shape]
    B = s.global_batch
    St = _text_len(cfg, s.seq_len)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if s.kind == "train":
        out = {"tokens": sds((B, St), i32), "labels": sds((B, St), i32)}
        if cfg.frontend != "none":
            out["extra_embeds"] = sds((B, cfg.frontend_seq, cfg.d_model), dtype)
        return out
    if s.kind == "prefill":
        out = {"tokens": sds((B, St), i32)}
        if cfg.frontend != "none":
            out["extra_embeds"] = sds((B, cfg.frontend_seq, cfg.d_model), dtype)
        return out
    # decode: one new token against a cache of seq_len
    out = {"tokens1": sds((B, 1), i32), "pos": sds((), i32)}
    if cfg.encdec:
        out["enc_out"] = sds((B, cfg.frontend_seq, cfg.d_model), dtype)
    return out


def cache_specs(cfg: ArchConfig, shape: str, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode caches (mirrors models.init_cache)."""
    s = SHAPES[shape]
    model = _build(cfg)
    caches = jax.eval_shape(
        lambda: model.init_cache(s.global_batch, s.seq_len, dtype))
    return caches


def _build(cfg):
    from ..models import build_model
    return build_model(cfg)


ARCH_NAMES = sorted(REGISTRY)
SHAPE_NAMES = list(SHAPES)


def all_cells():
    """The 40 (arch × shape) cells with applicability flags."""
    for a in ARCH_NAMES:
        cfg = REGISTRY[a]
        for sh in SHAPE_NAMES:
            ok, why = applicable(cfg, sh)
            yield a, sh, ok, why
