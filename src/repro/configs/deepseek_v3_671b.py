"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8 experts.

61 layers (first 3 dense, d_ff=18432), d_model=7168; routed expert FF=2048.
MoE uses expert-parallel all-to-all (shard_map EP). [arXiv:2412.19437; hf]
"""
from ..models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  capacity_factor=1.25, impl="ep_a2a"),
    n_dense_layers=3,
    rope_theta=10_000.0,
)
