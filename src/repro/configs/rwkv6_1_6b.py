"""rwkv6-1.6b [ssm] "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from ..models.config import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
)
