"""internvl2-26b [vlm]: InternLM2-20B-class backbone; InternViT frontend is a
STUB (input_specs provides 256 precomputed patch embeddings).
[arXiv:2404.16821; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553,
    mlp="swiglu", rope_theta=1_000_000.0,
    frontend="patch_stub", frontend_seq=256,
)
