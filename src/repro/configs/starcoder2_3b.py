"""starcoder2-3b [dense]: GQA, RoPE, GELU MLP with bias. [arXiv:2402.19173; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    qkv_bias=True, mlp="gelu", rope_theta=999_999.0,
)
