"""Trip-count-aware cost analysis of compiled HLO text.

XLA's ``Compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless
of trip count (verified by calibration: a scan of 8 matmuls reports 1 matmul
of FLOPs).  Layer-scanned models therefore under-report both FLOPs and
collective bytes by ~L×.  This module re-derives the §Roofline terms from
the post-SPMD HLO itself:

  * parse the module into computations,
  * recover each while-loop's trip count from its condition's comparison
    constant (the canonical scan lowering),
  * walk the call graph (fusions / calls / whiles / conditionals) weighting
    every op by the product of enclosing trip counts,
  * count dot FLOPs from shapes (2 x output_elems x contraction size),
    collective bytes from operand shapes, and bytes-accessed from each
    non-fused op's operand+result sizes (fusion internals excluded, matching
    HloCostAnalysis convention).

All counts are per-device (the module is the SPMD per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "s4": 1,
               "u4": 1, "token": 0, "opaque": 0}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                      r"called_computations)=\{?%?([\w\.\-]+)")
_CALLS_MULTI_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Total bytes of all shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape_elems(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return dt, n


@dataclass
class _Op:
    kind: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)


# symbol table: %value name -> dims list of its (first) result shape
_SYMBOLS: dict[str, list[int]] = {}


def _parse(hlo: str):
    comps: dict[str, _Computation] = {}
    symbols: dict[str, list[int]] = {}
    cur: _Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        # computation header: `[ENTRY] %name (args...) -> type {`
        # (argument lists contain nested parens: detect by suffix/arrow)
        if line.endswith("{") and "->" in line and "= " not in line.split("(")[0]:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        # op line: %name = type op-name(...), attrs
        om = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$", line)
        if not om:
            continue
        vname, rest = om.group(1), om.group(2)
        km = re.search(r"\s([a-z][\w\-]*)\(", " " + rest)
        kind = km.group(1) if km else "unknown"
        sm = _SHAPE_RE.search(rest)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            symbols[vname] = dims
        cur.ops.append(_Op(kind, line))
    return comps, symbols


def _trip_count(cond: _Computation) -> int:
    """Extract N from the canonical `iv < N` scan condition.

    The comparison may be wrapped in a fusion; the s32 length constant lives
    in the condition computation itself.
    """
    const = None
    for op in cond.ops:
        m = re.search(r"s32\[\]\s+constant\((\d+)\)", op.line)
        if m:
            const = int(m.group(1))
    return const or 1


def _dot_flops(line: str, symbols: dict) -> float:
    """2 * out_elems * contraction_size from an HLO dot line.

    Depending on the XLA version, operands appear either as bare ``%names``
    (shapes come from the symbol table) or with their shapes inlined
    (``dot(f32[64,64]{1,0} %x, ...)``) — the first shape in the argument
    list is then the lhs shape (a comma-split would cut inside ``[64,64]``).
    """
    sm = _SHAPE_RE.search(line.split("=", 1)[1])
    if not sm:
        return 0.0
    out_n = 1
    for d in sm.group(2).split(","):
        if d:
            out_n *= int(d)
    args = re.search(r"dot\(([^)]*)\)", line)
    lhs_dims: list[int] = []
    if args:
        inline = _SHAPE_RE.search(args.group(1))
        if inline:
            lhs_dims = [int(d) for d in inline.group(2).split(",") if d]
        else:
            first = args.group(1).split(",")[0].strip().lstrip("%")
            lhs_dims = symbols.get(first, [])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


def _children(line: str) -> list[str]:
    out = []
    for mm in re.finditer(r"(?:branch_computations|calls|"
                          r"called_computations)=\{([^}]*)\}", line):
        out += [c.strip().lstrip("%") for c in mm.group(1).split(",") if c]
    for attr in ("to_apply", "body", "condition", "calls"):
        m = re.search(attr + r"=%([\w\.\-]+)", line)
        if m:
            out.append(m.group(1))
    return out


def analyze_hlo(hlo: str) -> dict:
    comps, symbols = _parse(hlo)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        entry = next(iter(comps)) if comps else None
    memo: dict[str, dict] = {}

    def cost_of(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return {"flops": 0.0, "coll": {c: 0.0 for c in COLLECTIVES},
                    "bytes": 0.0}
        total = {"flops": 0.0, "coll": {c: 0.0 for c in COLLECTIVES},
                 "bytes": 0.0}
        comp = comps[name]
        for op in comp.ops:
            if op.kind == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                else:
                    trips = 1
                if body:
                    sub = cost_of(body, depth + 1)
                    total["flops"] += trips * sub["flops"]
                    total["bytes"] += trips * sub["bytes"]
                    for c in COLLECTIVES:
                        total["coll"][c] += trips * sub["coll"][c]
                continue
            if op.kind in ("fusion", "call", "conditional",
                           "async-start", "custom-call"):
                for child in _children(op.line):
                    if child in comps:
                        sub = cost_of(child, depth + 1)
                        # fusion children: count their dots/collectives but
                        # NOT their bytes (fusion is one memory op)
                        total["flops"] += sub["flops"]
                        for c in COLLECTIVES:
                            total["coll"][c] += sub["coll"][c]
                total["bytes"] += _shape_bytes(op.line)
                continue
            if op.kind == "dot":
                total["flops"] += _dot_flops(op.line, symbols)
                total["bytes"] += _shape_bytes(op.line)
                continue
            for c in COLLECTIVES:
                # count start ops only: `x-done` re-states the same payload
                if op.kind.startswith(c) and not op.kind.endswith("-done"):
                    dt, n = _first_shape_elems(op.line)
                    if dt in DTYPE_BYTES:
                        total["coll"][c] += n * DTYPE_BYTES[dt]
                    break
            total["bytes"] += _shape_bytes(op.line)
        memo[name] = total
        return total

    # computations reachable only via while/fusion are handled recursively;
    # start at entry
    out = cost_of(entry) if entry else {"flops": 0.0, "bytes": 0.0,
                                        "coll": {}}
    return {
        "flops": out["flops"],
        "bytes_accessed": out["bytes"],
        "collective_bytes": dict(out["coll"]),
        "collective_total": sum(out["coll"].values()),
        "n_computations": len(comps),
    }
