"""Batched serving entry point: prefill + decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --width tiny --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import build_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--width", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.width == "tiny":
        cfg = cfg.smoke_config().replace(remat=False)
    if cfg.frontend != "none":
        raise SystemExit("serve.py drives text-only archs; "
                         "see examples/ for the multimodal path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)

    B, Lp, G = args.batch, args.prompt_len, args.gen
    prompts = (jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 0,
                                  cfg.vocab)).astype(jnp.int32)

    caches = model.init_cache(B, Lp + G + 1, jnp.float32)

    @jax.jit
    def prefill(params, caches, toks):
        logits, caches = model.forward(params, toks, caches=caches,
                                       pos_offset=0)
        return logits[:, -1], caches

    @jax.jit
    def step(params, caches, tok, pos):
        return model.decode_step(params, tok, caches, pos)

    t0 = time.time()
    logits, caches = prefill(params, caches, prompts)
    t_prefill = time.time() - t0

    def pick(lg):
        return jnp.argmax(lg, -1).astype(jnp.int32)[:, None]

    tok = pick(logits)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, caches = step(params, caches, tok, Lp + i)
        tok = pick(logits)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prefill({Lp} tok)={t_prefill*1e3:.0f}ms "
          f"decode {G-1} steps @ {dt/(G-1)*1e3:.1f} ms/step")
    print("sample token ids:", gen[0, :16].tolist())
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
