"""Step functions (train / prefill / decode) for launch + dry-run.

These close over the model and optimizer config; the dry-run lowers them with
ShapeDtypeStruct inputs under the production mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import Model, ParallelCtx
from ..optim import AdamWConfig, apply_updates, init_state


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    ctx: ParallelCtx = ParallelCtx(),
                    microbatches: int = 1):
    """Training step, optionally with gradient accumulation.

    microbatches > 1 splits the global batch along dim 0 and lax.scans the
    forward+backward, accumulating grads in bf16 (sharded like params).
    Activation/transient memory scales down ~microbatches x; the optimizer
    update runs once on the mean gradient.
    """
    if microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, ctx))(params)
            new_params, new_state = apply_updates(opt_cfg, params, grads,
                                                  opt_state)
            return new_params, new_state, loss
        return train_step

    def train_step(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        mbs = jax.tree.map(split, batch)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                            params)

        def body(acc, mb):
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, mb, ctx))(params)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.bfloat16), acc, g)
            return acc, loss

        acc, losses = jax.lax.scan(body, acc0, mbs)
        grads = jax.tree.map(lambda a: a / microbatches, acc)
        new_params, new_state = apply_updates(opt_cfg, params, grads,
                                              opt_state)
        return new_params, new_state, losses.mean()
    return train_step


def make_prefill_step(model: Model, ctx: ParallelCtx = ParallelCtx()):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch["tokens"],
                                  extra_embeds=batch.get("extra_embeds"),
                                  ctx=ctx)
        # serving returns the last-position logits (next-token distribution)
        return logits[:, -1]
    return prefill_step


def make_decode_step(model: Model, ctx: ParallelCtx = ParallelCtx()):
    cfg = model.cfg

    def decode_step(params, caches, batch):
        kw = {}
        if cfg.encdec:
            kw["enc_out"] = batch["enc_out"]
        logits, new_caches = model.decode_step(params, batch["tokens1"],
                                               caches, batch["pos"], **kw)
        return logits, new_caches
    return decode_step


def init_all(model: Model, opt_cfg: AdamWConfig, key,
             dtype=jnp.bfloat16):
    params = model.init(key, dtype)
    opt_state = init_state(opt_cfg, params)
    return params, opt_state
