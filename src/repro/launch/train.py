"""End-to-end training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --batch 8 --seq 256 --width tiny

``--width tiny`` uses the reduced same-family config (CPU-runnable: this is
example (b)'s ~100M-class driver); ``--width full`` uses the assigned config
(real hardware).  The driver provides prefetch, async checkpointing and
restart; optimizer is AdamW with cosine schedule.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import DataConfig
from ..models import build_model
from ..optim import AdamWConfig, apply_updates, init_state
from ..runtime import DriverConfig, TrainDriver


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--width", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.width == "tiny":
        cfg = cfg.smoke_config().replace(
            d_model=128, d_ff=384, n_layers=max(2, min(cfg.n_layers, 4)),
            vocab=2048, remat=False)
    model = build_model(cfg)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16

    opt_cfg = AdamWConfig(lr=args.lr, warmup=20, total_steps=args.steps)

    def init_fn():
        params = model.init(jax.random.PRNGKey(0), dtype)
        return params, init_state(opt_cfg, params)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        params, opt_state = apply_updates(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch,
                          frontend_seq=cfg.frontend_seq if cfg.frontend != "none" else 0,
                          d_model=cfg.d_model)
    drv_cfg = DriverConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                           ckpt_dir=args.ckpt_dir)
    driver = TrainDriver(drv_cfg, data_cfg, train_step, init_fn)

    t0 = time.time()
    hist = driver.run()
    dt = time.time() - t0
    first = hist[0].loss
    last = sum(h.loss for h in hist[-5:]) / min(5, len(hist))
    print(f"arch={cfg.name} steps={len(hist)} loss {first:.4f} -> {last:.4f} "
          f"({dt:.1f}s, {dt/max(1,len(hist))*1e3:.0f} ms/step, "
          f"restarts={driver.restarts})")
    assert last < first, "loss did not go down"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
