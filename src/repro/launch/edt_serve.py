"""Schedule-service entry point: parametric graphs answered from the cache.

    PYTHONPATH=src python -m repro.launch.edt_serve --program jacobi2d \
        --tile 2,2,2 --backend numpy --shards 2 --demo

Serves "give me the schedule / packed arrays for program P at size N"
requests through :class:`repro.core.edt.service.ScheduleService`: cold
misses materialize on the sharded pool (with retry/backoff recovery when
``--retries`` is set), warm hits answer sub-millisecond from the graph
cache.  Two modes:

* ``--demo`` — a scripted burst: several sizes requested by many
  concurrent clients (duplicates coalesce), then the same sizes again
  (all warm); prints per-request latencies and the service stats.
* default — a line protocol on stdin, one JSON request per line::

      {"params": {"T": 8, "N": 64}, "kind": "schedule"}

  answered on stdout with task/edge/depth counts, warm/cold status, and
  latency; EOF prints the final stats.  (``kind`` ∈ graph | schedule |
  packed, default schedule.)

The existing LLM server (``repro.launch.serve``) is a different entry
point and is untouched by this one.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time

from ..core import programs
from ..core.edt.config import CachePolicy, ExecutionConfig, Session
from ..core.edt.service import ScheduleService
from ..core.poly import Tiling


def build_session(args) -> tuple[Session, object]:
    recovery = None
    if args.retries:
        from ..core.edt.recovery import RetryPolicy
        recovery = RetryPolicy(max_retries=args.retries)
    cfg = ExecutionConfig(
        backend=args.backend, shards=args.shards or None, recovery=recovery,
        cache=CachePolicy(max_entries=args.cache_entries,
                          max_bytes=args.cache_bytes))
    session = Session(cfg)
    program = programs.PROGRAMS[args.program]()
    sizes = tuple(int(x) for x in args.tile.split(","))
    tilings = {name: Tiling(sizes) for name in program.statements}
    return session, session.graph(program, tilings)


def _describe(kind: str, result) -> dict:
    if kind == "graph":
        return {"tasks": result.n, "edges": result.n_edges}
    if kind == "schedule":
        ig, sched = result
        return {"tasks": ig.n, "edges": ig.n_edges, "depth": sched.depth}
    dg, ds = result
    return {"tasks": dg.n, "edges": dg.n_edges, "depth": ds.depth}


async def serve_stdin(service: ScheduleService, graph, out=sys.stdout) -> int:
    loop = asyncio.get_running_loop()
    while True:
        line = await loop.run_in_executor(None, sys.stdin.readline)
        if not line:
            break
        line = line.strip()
        if not line:
            continue
        t0 = time.perf_counter()
        try:
            req = json.loads(line)
            kind = req.get("kind", "schedule")
            warm = service.session.cache.peek(
                graph, req["params"],
                {"graph": "ig", "schedule": "schedule",
                 "packed": "ds"}[kind]) is not None
            result = await getattr(service, {"graph": "index_graph"}.get(
                kind, kind))(graph, req["params"])
            resp = {"ok": True, "warm": warm,
                    "ms": round((time.perf_counter() - t0) * 1e3, 3)}
            resp.update(_describe(kind, result))
        except Exception as e:  # noqa: BLE001 — protocol: report, keep serving
            resp = {"ok": False, "error": repr(e)}
        print(json.dumps(resp), file=out, flush=True)
    print(json.dumps({"stats": service.stats()}), file=out, flush=True)
    return 0


async def demo(service: ScheduleService, graph, args, out=sys.stdout) -> int:
    pnames = graph.param_names
    sizes = []
    for n in (args.size, args.size + args.size // 2, 2 * args.size):
        p = dict.fromkeys(pnames, n)
        if "T" in p:
            p["T"] = max(2, n // 4)
        sizes.append(p)

    async def one(params, kind):
        t0 = time.perf_counter()
        await getattr(service, kind)(graph, params)
        return (time.perf_counter() - t0) * 1e3

    # burst: every size requested by `--clients` concurrent clients
    reqs = [(p, "schedule") for p in sizes for _ in range(args.clients)]
    t0 = time.perf_counter()
    lat = await asyncio.gather(*(one(p, k) for p, k in reqs))
    cold_s = time.perf_counter() - t0
    print(f"cold burst: {len(reqs)} requests over {len(sizes)} keys in "
          f"{cold_s * 1e3:.1f} ms (max client latency {max(lat):.1f} ms)",
          file=out)
    # warm pass: same keys, now answered from the cache
    t0 = time.perf_counter()
    lat = await asyncio.gather(*(one(p, k) for p, k in reqs))
    warm_s = time.perf_counter() - t0
    print(f"warm burst: same {len(reqs)} requests in {warm_s * 1e3:.2f} ms "
          f"(max client latency {max(lat):.3f} ms)", file=out)
    print(json.dumps({"stats": service.stats()}, indent=2), file=out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--program", default="jacobi2d",
                    choices=sorted(programs.PROGRAMS))
    ap.add_argument("--tile", default="2,2,2",
                    help="comma-separated tile sizes (must match the "
                         "program's dimensionality)")
    ap.add_argument("--backend", default="numpy",
                    choices=["fraction", "compiled", "numpy"])
    ap.add_argument("--shards", type=int, default=0,
                    help="fan cold scans across N processes (0 = in-process)")
    ap.add_argument("--retries", type=int, default=0,
                    help="arm shard recovery with this retry budget")
    ap.add_argument("--cache-entries", type=int, default=32)
    ap.add_argument("--cache-bytes", type=int, default=2**30)
    ap.add_argument("--demo", action="store_true",
                    help="run the scripted concurrent burst instead of stdin")
    ap.add_argument("--size", type=int, default=24,
                    help="base parameter value for --demo sizes")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent clients per key in --demo")
    args = ap.parse_args(argv)

    session, graph = build_session(args)
    with session:
        service = ScheduleService(session)
        try:
            if args.demo:
                return asyncio.run(demo(service, graph, args))
            return asyncio.run(serve_stdin(service, graph))
        finally:
            service.close()


if __name__ == "__main__":
    sys.exit(main())
