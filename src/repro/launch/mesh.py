"""Production mesh construction.

Never touches jax device state at import time: everything is a function.
Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the 'pod'
axis carries only data parallelism + ZeRO gathers (cross-pod DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
