import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the first import side effect: the XLA_FLAGS above create 512 host
placeholder devices before jax locks the device count (hence the unusual
module layout — do not move the docstring above the env mutation).

For each cell this driver:
  1. builds the model + step function (train / prefill / decode),
  2. computes parameter/optimizer/input shardings from repro.parallel rules,
  3. ``jit(...).lower(ShapeDtypeStructs).compile()`` under the mesh,
  4. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
     operand bytes parsed from the compiled HLO into
     ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` (§Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (REGISTRY, SHAPES, applicable, get_config, input_specs)
from ..models import ParallelCtx, build_model
from ..optim import AdamWConfig, init_state
from ..parallel.sharding import (batch_specs, cache_specs_tree, dp_axes,
                                 opt_state_specs, param_specs, to_named)
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh
from .steps import make_decode_step, make_prefill_step, make_train_step

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in post-SPMD HLO."""
    out = {c: 0 for c in COLLECTIVES}
    count = {c: 0 for c in COLLECTIVES}
    # lines look like:  %all-reduce.5 = f32[1024,512]{1,0} all-reduce(...)
    shape_re = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8,
                   "u64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                   "f8e5m2": 1, "c64": 8}
    for line in hlo_text.splitlines():
        for c in COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                m = shape_re.search(line)
                if not m:
                    continue
                dt, dims = m.group(1), m.group(2)
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                out[c] += n * dtype_bytes.get(dt, 4)
                count[c] += 1
                break
    return {"bytes": out, "counts": count,
            "total_bytes": sum(out.values())}


def _mem_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                            None),
            "peak_bytes": (getattr(ma, "temp_size_in_bytes", 0) or 0) +
                          (getattr(ma, "argument_size_in_bytes", 0) or 0) +
                          (getattr(ma, "output_size_in_bytes", 0) or 0),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_summary(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {"flops": ca.get("flops"),
                "bytes_accessed": ca.get("bytes accessed"),
                "optimal_seconds": ca.get("optimal_seconds")}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def build_cell(arch: str, shape: str, mesh, *, opt_bits: int = 0,
               extra_cfg: dict | None = None, microbatches: int = 1):
    """Returns (jitted_fn, example_args_SDS) for the cell, ready to lower.

    opt_bits=0 means auto: 8-bit moment states when f32 states would not fit
    the 16 GB/chip budget (params*10B/chip > 12 GB), else f32.
    """
    cfg = get_config(arch)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    if opt_bits == 0:
        opt_bits = 8 if cfg.n_params() * 10 / mesh.size > 12e9 else 32
    sspec = SHAPES[shape]
    model = build_model(cfg)
    dps = dp_axes(mesh)
    dp = dps if len(dps) > 1 else dps[0]
    ctx = ParallelCtx(ep_axis="model", ep_size=mesh.shape["model"],
                      mesh=mesh, dp_spec=dp)

    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda k: model.init(k, jnp.bfloat16), key)
    pspecs = param_specs(p_shapes, mesh)
    in_sds = input_specs(cfg, shape)
    bspecs = batch_specs(in_sds, mesh)

    if sspec.kind == "train":
        opt_cfg = AdamWConfig(state_bits=opt_bits)
        o_shapes = jax.eval_shape(lambda: init_state(opt_cfg, p_shapes))
        ospecs = opt_state_specs(o_shapes, pspecs, mesh, zero=True)
        step = make_train_step(model, opt_cfg, ctx,
                               microbatches=microbatches)
        fn = jax.jit(step,
                     in_shardings=(to_named(pspecs, mesh),
                                   to_named(ospecs, mesh),
                                   to_named(bspecs, mesh)),
                     out_shardings=(to_named(pspecs, mesh),
                                    to_named(ospecs, mesh),
                                    NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        args = (p_shapes, o_shapes, in_sds)
    elif sspec.kind == "prefill":
        step = make_prefill_step(model, ctx)
        fn = jax.jit(step,
                     in_shardings=(to_named(pspecs, mesh),
                                   to_named(bspecs, mesh)),
                     out_shardings=NamedSharding(mesh, P()))
        args = (p_shapes, in_sds)
    else:  # decode
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(sspec.global_batch, sspec.seq_len,
                                     jnp.bfloat16))
        cspecs = cache_specs_tree(c_shapes, mesh)
        step = make_decode_step(model, ctx)
        fn = jax.jit(step,
                     in_shardings=(to_named(pspecs, mesh),
                                   to_named(cspecs, mesh),
                                   to_named(bspecs, mesh)),
                     out_shardings=(NamedSharding(mesh, P()),
                                    to_named(cspecs, mesh)),
                     donate_argnums=(1,))
        args = (p_shapes, c_shapes, in_sds)
    return cfg, fn, args


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             opt_bits: int = 0, save: bool = True,
             extra_cfg: dict | None = None, tag: str = "",
             microbatches: int = 1) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg0 = get_config(arch)
    ok, why = applicable(cfg0, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "opt_bits": opt_bits, "tag": tag}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        _save(rec, save)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg, fn, args = build_cell(arch, shape, mesh, opt_bits=opt_bits,
                                   extra_cfg=extra_cfg,
                                   microbatches=microbatches)
        t1 = time.time()
        lowered = fn.lower(*args)
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        hc = analyze_hlo(hlo)   # trip-count-corrected (see hlo_cost.py)
        rec.update({
            "hlo_cost": hc,
            "status": "ok",
            "n_devices": mesh.size,
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            "build_s": round(t1 - t0, 2),
            "lower_s": round(t2 - t1, 2),
            "compile_s": round(t3 - t2, 2),
            "memory": _mem_summary(compiled),
            "cost": _cost_summary(compiled),
            "collectives": coll,
            "hlo_bytes": len(hlo),
        })
    except Exception as e:
        rec.update({"status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:]})
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool):
    if not save:
        return
    ART.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    f = ART / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    f.write_text(json.dumps(rec, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--opt-bits", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        pass
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod:
        meshes.append(True)
    if not meshes:
        meshes = [False, True]

    cells = []
    if args.all:
        for a in sorted(REGISTRY):
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for a, s in cells:
            rec = run_cell(a, s, mp, opt_bits=args.opt_bits, tag=args.tag)
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            mem = rec.get("memory", {}).get("peak_bytes")
            mem_s = f"{mem/2**30:.2f}GiB/dev" if mem else "-"
            flops = rec.get("cost", {}).get("flops")
            fl_s = f"{flops:.3e}" if flops else "-"
            print(f"[{rec['mesh']}] {a:24s} {s:12s} {st:8s} "
                  f"mem={mem_s:14s} flops={fl_s} "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"{rec.get('reason', '') or rec.get('error', '')}",
                  flush=True)
            if st == "ok":
                print("  memory_analysis:", json.dumps(rec["memory"]))
                print("  cost_analysis:", json.dumps(rec["cost"]))
                print("  collectives:",
                      json.dumps(rec["collectives"]["bytes"]))
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} error={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
