"""RWKV6 ("Finch") language model: attention-free, O(S) compute, O(1) state."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .ssm import (rwkv6_channel_mix, rwkv6_params, rwkv6_time_mix)
from .transformer import ParallelCtx, _stack, seq_shard


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    ke, kl, ko = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(cfg.d_model)

    def layer(k):
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "mix": rwkv6_params(k, cfg, dtype)}

    p = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * s
                  ).astype(dtype),
        "ln_in": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "layers": _stack(kl, cfg.n_layers, layer),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ko, (cfg.d_model, cfg.vocab)) * s
                        ).astype(dtype)
    return p


def _ln(w, x, eps):
    xf = x.astype(jnp.float32)
    return (w * (xf * jax.lax.rsqrt(
        jnp.mean(xf * xf, -1, keepdims=True) + eps))).astype(x.dtype)


def forward(cfg: ArchConfig, params, tokens, *, caches=None, pos_offset=0,
            ctx: ParallelCtx = ParallelCtx(), window=None, extra_embeds=None):
    del extra_embeds  # attention-free LM has no modality frontend
    x = params["embed"][tokens]
    x = _ln(params["ln_in"], x, cfg.rms_eps)

    def body(h, inp):
        p, cache = inp
        tm_cache = None if cache is None else cache["tm"]
        cm_cache = None if cache is None else cache["cm"]
        a, tm_new = rwkv6_time_mix(p["mix"], _ln(p["ln1"], h, cfg.rms_eps),
                                   cfg, cache=tm_cache,
                                   use_kernel=(cfg.attn_impl == "pallas"))
        h = h + a
        c, cm_new = rwkv6_channel_mix(p["mix"], _ln(p["ln2"], h, cfg.rms_eps),
                                      cache=cm_cache)
        h = seq_shard(h + c, ctx)
        nc = None if cache is None else {"tm": tm_new, "cm": cm_new}
        return h, nc

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = _ln(params["ln_f"], x, cfg.rms_eps)
    logits = x @ (params["embed"].T if cfg.tie_embeddings
                  else params["unembed"])
    return logits, new_caches


def loss_fn(cfg: ArchConfig, params, batch, ctx: ParallelCtx = ParallelCtx()):
    from .transformer import xent
    logits, _ = forward(cfg, params, batch["tokens"], ctx=ctx)
    return xent(logits, batch["labels"], ctx)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = d // cfg.rwkv.head_dim
    D = cfg.rwkv.head_dim
    one = {
        "tm": {"shift": jnp.zeros((batch, 1, d), dtype),
               "wkv": jnp.zeros((batch, H, D, D), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, 1, d), dtype)},
    }
    return jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), one)


def decode_step(cfg, params, tokens1, caches, pos,
                ctx: ParallelCtx = ParallelCtx()):
    logits, new_caches = forward(cfg, params, tokens1, caches=caches,
                                 pos_offset=pos, ctx=ctx)
    return logits[:, -1], new_caches
