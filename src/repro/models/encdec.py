"""Whisper-backbone encoder-decoder (conv frontend is a stub per assignment).

Inputs: ``frames`` [B, S_audio, d_model] — precomputed frame embeddings (the
stub for the mel-spectrogram conv stem) — and decoder ``tokens`` [B, S_text].
Encoder = bidirectional self-attention; decoder = causal self-attention +
cross-attention to the encoder output.

In the EDT view this is a two-statement polyhedral program whose cross-
attention dependences form a genuinely non-tree task graph (the paper's
diamond case): every decoder tile depends on every encoder tile.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (attention_core, gqa_apply, gqa_params, mlp_apply,
                     mlp_params, rmsnorm)
from .transformer import ParallelCtx, _stack


def _xattn_params(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {"wq": (jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
            "wk": (jax.random.normal(k2, (d, H * hd)) * s).astype(dtype),
            "wv": (jax.random.normal(k3, (d, H * hd)) * s).astype(dtype),
            "wo": (jax.random.normal(k4, (H * hd, d)) * s).astype(dtype)}


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    ke, kenc, kdec, ko, kp = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(cfg.d_model)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "attn": gqa_params(k1, cfg, dtype),
                "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "ln_x": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "attn": gqa_params(k1, cfg, dtype),
                "xattn": _xattn_params(k2, cfg, dtype),
                "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}

    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * s
                  ).astype(dtype),
        "enc_pos": (jax.random.normal(kp, (8192, cfg.d_model)) * 0.01
                    ).astype(dtype),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "enc_layers": _stack(kenc, cfg.n_encoder_layers, enc_layer),
        "dec_layers": _stack(kdec, cfg.n_layers, dec_layer),
        "unembed": (jax.random.normal(ko, (cfg.d_model, cfg.vocab)) * s
                    ).astype(dtype),
    }


def encode(cfg: ArchConfig, params, frames):
    B, S, _ = frames.shape
    pe = params["enc_pos"]
    if S > pe.shape[0]:
        reps = -(-S // pe.shape[0])
        pe = jnp.tile(pe, (reps, 1))
    x = frames + pe[None, :S]
    positions = jnp.arange(S)

    def body(h, p):
        a, _ = gqa_apply(p["attn"], rmsnorm(p["ln1"], h, cfg.rms_eps), cfg,
                         positions=positions, causal=False)
        h = h + a
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.rms_eps), cfg.mlp)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["ln_enc"], x, cfg.rms_eps)


def _cross_attend(p, x, enc_kv, cfg):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd()
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    Sk = k.shape[1]
    out = attention_core(q, k, v, causal=False,
                         q_pos=jnp.arange(S), kv_pos=jnp.arange(Sk))
    return out.reshape(B, S, H * hd) @ p["wo"]


def decode(cfg: ArchConfig, params, tokens, enc_out, *, caches=None,
           pos_offset=0):
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S) + pos_offset
    H, hd = cfg.n_heads, cfg.hd()

    # Precompute per-layer cross K/V from encoder output (cacheable).
    def xkv(p):
        k = (enc_out @ p["xattn"]["wk"]).reshape(B, -1, H, hd)
        v = (enc_out @ p["xattn"]["wv"]).reshape(B, -1, H, hd)
        return k, v

    def body(h, inp):
        p, cache = inp
        a, nc = gqa_apply(p["attn"], rmsnorm(p["ln1"], h, cfg.rms_eps), cfg,
                          positions=positions, cache=cache)
        h = h + a
        k, v = xkv(p)
        h = h + _cross_attend(p["xattn"], rmsnorm(p["ln_x"], h, cfg.rms_eps),
                              (k, v), cfg)
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.rms_eps), cfg.mlp)
        return h, nc

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = rmsnorm(params["ln_f"], x, cfg.rms_eps)
    return x @ params["unembed"], new_caches


def forward(cfg: ArchConfig, params, tokens, *, extra_embeds=None,
            caches=None, pos_offset=0, ctx: ParallelCtx = ParallelCtx(),
            window=None):
    assert extra_embeds is not None, "enc-dec needs frame embeddings"
    enc = encode(cfg, params, extra_embeds)
    return decode(cfg, params, tokens, enc, caches=caches,
                  pos_offset=pos_offset)


def loss_fn(cfg: ArchConfig, params, batch, ctx: ParallelCtx = ParallelCtx()):
    from .transformer import xent
    logits, _ = forward(cfg, params, batch["tokens"],
                        extra_embeds=batch["extra_embeds"])
    return xent(logits, batch["labels"], ctx)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.hd()
    one = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
           "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
           "len": jnp.zeros((), jnp.int32)}
    return jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), one)


def decode_step(cfg, params, tokens1, caches, pos, *, enc_out,
                ctx: ParallelCtx = ParallelCtx()):
    logits, nc = decode(cfg, params, tokens1, enc_out, caches=caches,
                        pos_offset=pos)
    return logits[:, -1], nc
