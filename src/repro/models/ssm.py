"""State-space blocks: Mamba2 (SSD, chunked) and RWKV6 (data-dependent decay).

Both are written as chunked scans (`lax.scan` over sequence chunks with a
constant-size carried state), which is what makes the ``long_500k`` shapes
lowerable: compute is O(S), state is O(1) in sequence length.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig


# =====================================================================
# Mamba2 (SSD — state space duality, chunked algorithm)
# =====================================================================
def mamba2_params(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    return {
        # fused in_proj -> [z, x, B, C, dt]
        "win": (jax.random.normal(ks[0], (d, 2 * di + 2 * s.d_state + nh)) * sc
                ).astype(dtype),
        "conv": (jax.random.normal(ks[1], (s.d_conv, di + 2 * s.d_state))
                 * 0.1).astype(dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "wout": (jax.random.normal(ks[2], (di, d)) / math.sqrt(di)).astype(dtype),
    }


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD: xh [B,S,H,P], dt [B,S,H] (>=0), A [H] (<0 decay rate),
    Bm/Cm [B,S,N].  Returns (y [B,S,H,P], final_state [B,H,P,N]).

    Within a chunk the quadratic (attention-like) form is used; across chunks
    a recurrent state [H, P, N] is carried — O(S) total work.
    """
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = xh.reshape(B, nc, chunk, H, Pd)
    dtc = dt.reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    dA = dtc * A[None, None, None, :]              # [B,nc,L,H] (negative)
    cums = jnp.cumsum(dA, axis=2)                  # within-chunk cumulative

    def body(state, inp):
        xb, dtb, Bb, Cb, dAb, cumb = inp           # [B,L,...]
        # decay from chunk start to position t: exp(cum[t])
        seg = jnp.exp(cumb)                        # [B,L,H]
        # inter-chunk: contribution of incoming state
        y_state = jnp.einsum("bln,bhpn->blhp", Cb, state) * seg[..., None]
        # intra-chunk quadratic form: L x L decay matrix per head
        rel = cumb[:, :, None, :] - cumb[:, None, :, :]      # [B,L,L,H]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bln,bmn->blm", Cb, Bb)[..., None] * decay
        y_intra = jnp.einsum("blmh,bmh,bmhp->blhp", scores, dtb, xb)
        # state update: carry to end of chunk
        chunk_decay = jnp.exp(cums_last := cumb[:, -1:, :])  # [B,1,H]
        w = jnp.exp(cumb[:, -1:, :] - cumb)                  # decay t..end
        state_new = state * chunk_decay[:, 0, :, None, None] + jnp.einsum(
            "blh,blhp,bln->bhpn", dtb * w, xb, Bb)
        return state_new, y_state + y_intra

    if init_state is None:
        init_state = jnp.zeros((B, H, Pd, N), jnp.float32)
    inps = (xc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
            dtc.transpose(1, 0, 2, 3).astype(jnp.float32),
            Bc.transpose(1, 0, 2, 3).astype(jnp.float32),
            Cc.transpose(1, 0, 2, 3).astype(jnp.float32),
            dA.transpose(1, 0, 2, 3),
            cums.transpose(1, 0, 2, 3))
    # recompute the [L,L,H] intra-chunk decay/score tensors in backward
    # instead of saving them per chunk (they dominate memory otherwise)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(body, init_state, inps)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Pd)
    return y, state


def mamba2_apply(p, x, cfg: ArchConfig, *, cache: Optional[dict] = None):
    """Mamba2 block.  cache = {'conv': [B,d_conv-1,Ci], 'ssm': [B,H,P,N]}
    enables O(1) decode steps."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.expand * d
    nh = di // s.head_dim
    N = s.d_state
    proj = x @ p["win"]
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)       # [B,S,di+2N]

    new_cache = None
    if cache is not None:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)
        conv_src = hist[:, -(S + s.d_conv - 1):]
        new_conv = hist[:, -(s.d_conv - 1):]
    else:
        conv_src = jnp.pad(conv_in, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(s.d_conv - 1):]

    # causal depthwise conv1d
    idx = jnp.arange(S)[:, None] + jnp.arange(s.d_conv)[None, :]
    win = conv_src[:, idx]                                   # [B,S,K,C]
    conv_out = jax.nn.silu(jnp.einsum("bskc,kc->bsc", win, p["conv"]))
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                 # [H], negative
    xh = xin.reshape(B, S, nh, s.head_dim)

    if S == 1:                                               # recurrent decode
        state = (cache["ssm"] if cache is not None
                 else jnp.zeros((B, nh, s.head_dim, N), jnp.float32))
        dA = jnp.exp(dt[:, 0] * A[None, :])                  # [B,H]
        st = state * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0].astype(jnp.float32),
            Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), st)
        y = y[:, None] .reshape(B, 1, nh, s.head_dim)
        new_state = st
    else:
        chunk = min(s.chunk, S)
        pad = (-S) % chunk
        init = cache["ssm"] if cache is not None else None
        if pad:
            # dt=0 on padding => decay 1, contribution 0: state is unchanged
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            y, new_state = _ssd_chunk_scan(xh_p, dt_p, A, Bm_p, Cm_p,
                                           chunk, init)
            y = y[:, :S]
        else:
            y, new_state = _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk, init)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (Mamba2 style)
    y = y * jax.nn.silu(z)
    dtp = y.dtype
    yf = y.astype(jnp.float32)
    y = (p["norm"] * (yf * jax.lax.rsqrt(
        jnp.mean(yf * yf, -1, keepdims=True) + cfg.rms_eps))).astype(dtp)
    out = y @ p["wout"]
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_state}
    return out, new_cache


# =====================================================================
# RWKV6 ("Finch"): token shift + data-dependent decay WKV
# =====================================================================
def rwkv6_params(key, cfg: ArchConfig, dtype):
    r = cfg.rwkv
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    sc = 1.0 / math.sqrt(d)
    nh = d // r.head_dim
    return {
        "mix_rkvwg": jnp.full((5, d), 0.5, dtype),     # token-shift mixes
        "wr": (jax.random.normal(ks[0], (d, d)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * sc).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, d)) * sc).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),       # decay bias
        "w_lora_a": (jax.random.normal(ks[4], (d, r.decay_lora)) * sc).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[5], (r.decay_lora, d)) * 0.1).astype(dtype),
        "u": (jax.random.normal(ks[6], (nh, r.head_dim)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), dtype),
        "wo": (jax.random.normal(ks[7], (d, d)) * sc).astype(dtype),
        # channel-mix
        "mix_cm": jnp.full((2, d), 0.5, dtype),
        "ck": (jax.random.normal(ks[8], (d, cfg.d_ff)) * sc).astype(dtype),
        "cv": (jax.random.normal(ks[9], (cfg.d_ff, d)) / math.sqrt(cfg.d_ff)).astype(dtype),
        "cr": (jax.random.normal(ks[10], (d, d)) * sc).astype(dtype),
    }


def _wkv6_scan(r, k, v, w, u, init_state=None, chunk: int = 64):
    """WKV6 recurrence as a two-level (chunked) scan.

    r,k,v: [B,S,H,D]; w: [B,S,H,D] per-channel decay in (0,1);
    u: [H,D] bonus. state: [B,H,D,D] (key x value outer products).
    out[t] = (state + u * k_t ⊗ v_t) . r_t ;  state = w_t*state + k_t ⊗ v_t.

    The outer scan carries the state between chunks and its body is
    rematerialized in backward (`jax.checkpoint`), so training memory is
    O(S/chunk) states instead of O(S) — same structure as the Pallas kernel.
    """
    B, S, H, D = r.shape
    state = init_state if init_state is not None else jnp.zeros((B, H, D, D), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # w=1 on padding => state unchanged; outputs discarded
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    Sp = S + pad
    nc = Sp // chunk

    def to_chunks(x):
        return (x.reshape(B, nc, chunk, H, D).transpose(1, 2, 0, 3, 4)
                .astype(jnp.float32))            # [nc, C, B, H, D]

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w))

    def chunk_body(st, inp):
        rc, kc, vc, wc = inp                      # [C, B, H, D]

        def step(s, t_inp):
            rt, kt, vt, wt = t_inp
            kv = kt[..., :, None] * vt[..., None, :]
            out = jnp.einsum("bhd,bhde->bhe", rt,
                             s + u[None, :, :, None] * kv)
            s = wt[..., :, None] * s + kv
            return s, out

        st, outs = jax.lax.scan(step, st, (rc, kc, vc, wc))
        return st, outs                           # outs [C, B, H, D]

    chunk_body = jax.checkpoint(
        chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    state, outs = jax.lax.scan(chunk_body, state, xs)  # [nc, C, B, H, D]
    outs = outs.reshape(Sp, B, H, D).transpose(1, 0, 2, 3)[:, :S]
    return outs.astype(r.dtype), state


def rwkv6_time_mix(p, x, cfg: ArchConfig, *, cache: Optional[dict] = None,
                   use_kernel: bool = False):
    r_cfg = cfg.rwkv
    B, S, d = x.shape
    H = d // r_cfg.head_dim
    D = r_cfg.head_dim
    last = cache["shift"] if cache is not None else jnp.zeros((B, 1, d), x.dtype)
    xs = jnp.concatenate([last, x[:, :-1]], axis=1)          # token shift
    mixed = [x + (xs - x) * p["mix_rkvwg"][i] for i in range(5)]
    r = (mixed[0] @ p["wr"]).reshape(B, S, H, D)
    k = (mixed[1] @ p["wk"]).reshape(B, S, H, D)
    v = (mixed[2] @ p["wv"]).reshape(B, S, H, D)
    g = jax.nn.silu(mixed[4] @ p["wg"])
    wdec = p["w0"] + (jnp.tanh(mixed[3] @ p["w_lora_a"]) @ p["w_lora_b"]
                      ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wdec)).reshape(B, S, H, D)          # (0,1)

    init = cache["wkv"] if cache is not None else None
    if use_kernel and S > 1:
        from ..kernels import ops as kops
        out, state = kops.wkv6(r, k, v, w, p["u"], init_state=init)
    else:
        out, state = _wkv6_scan(r, k, v, w, p["u"], init_state=init)
    out = out.reshape(B, S, d)
    dt = x.dtype
    of = out.astype(jnp.float32)
    out = (p["ln_x"] * (of * jax.lax.rsqrt(
        jnp.mean(of * of, -1, keepdims=True) + cfg.rms_eps))).astype(dt)
    out = (out * g) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1:], "wkv": state}
    return out, new_cache


def rwkv6_channel_mix(p, x, *, cache=None):
    B, S, d = x.shape
    last = cache["shift"] if cache is not None else jnp.zeros((B, 1, d), x.dtype)
    xs = jnp.concatenate([last, x[:, :-1]], axis=1)
    xk = x + (xs - x) * p["mix_cm"][0]
    xr = x + (xs - x) * p["mix_cm"][1]
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    out = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    return out, ({"shift": x[:, -1:]} if cache is not None else None)
