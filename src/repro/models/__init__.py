"""Model zoo: a uniform functional interface over all architecture families."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from . import encdec, hybrid, rwkv, transformer
from .config import (ArchConfig, MLAConfig, MoEConfig, RWKVConfig, SSMConfig)
from .transformer import ParallelCtx


@dataclass(frozen=True)
class Model:
    """Uniform handle: every family exposes the same six functions."""
    cfg: ArchConfig
    init: Callable          # (key, dtype) -> params
    loss: Callable           # (params, batch, ctx) -> scalar
    forward: Callable        # (params, tokens, **kw) -> (logits, caches)
    init_cache: Callable     # (batch, max_len, dtype) -> caches
    decode_step: Callable    # (params, tokens1, caches, pos, ctx) -> (logits, caches)


def _family_module(cfg: ArchConfig):
    if cfg.encdec:
        return encdec
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return rwkv
    return transformer


def build_model(cfg: ArchConfig) -> Model:
    mod = _family_module(cfg)
    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.bfloat16: mod.init_params(cfg, key, dtype),
        loss=lambda params, batch, ctx=ParallelCtx(): mod.loss_fn(
            cfg, params, batch, ctx),
        forward=lambda params, tokens, **kw: mod.forward(
            cfg, params, tokens, **kw),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: mod.init_cache(
            cfg, batch, max_len, dtype),
        decode_step=lambda params, t1, caches, pos, **kw: mod.decode_step(
            cfg, params, t1, caches, pos, **kw),
    )


__all__ = ["ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
           "Model", "ParallelCtx", "build_model"]
