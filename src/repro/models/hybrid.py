"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The shared transformer block (attention + MLP with its own weights) is applied
every ``cfg.shared_attn_every`` layers, with the same weights each time
(Zamba2's parameter-sharing trick).  SSM layers carry constant-size state, so
``long_500k`` decoding is O(1) memory per token; the shared attention block
uses a sliding window at long context (deviation recorded in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import gqa_apply, gqa_params, mlp_apply, mlp_params, rmsnorm
from .ssm import mamba2_apply, mamba2_params
from .transformer import ParallelCtx, _stack, seq_shard


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    ke, km, ka, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(cfg.d_model)
    n_ssm = sum(1 for i in range(cfg.n_layers)
                if not _is_attn_layer(cfg, i))

    def ssm_layer(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": mamba2_params(k, cfg, dtype)}

    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * s
                  ).astype(dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "ssm_layers": _stack(km, n_ssm, ssm_layer),
        "shared_attn": {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": gqa_params(ka, cfg, dtype),
            "mlp": mlp_params(ko, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
        },
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(ko, (cfg.d_model, cfg.vocab))
                             * s).astype(dtype)
    return params


def _is_attn_layer(cfg: ArchConfig, i: int) -> bool:
    k = cfg.shared_attn_every
    return k > 0 and (i + 1) % k == 0


def _n_ssm(cfg):
    return sum(1 for i in range(cfg.n_layers) if not _is_attn_layer(cfg, i))


def _n_attn(cfg):
    return cfg.n_layers - _n_ssm(cfg)


def forward(cfg: ArchConfig, params, tokens, *, caches=None, pos_offset=0,
            ctx: ParallelCtx = ParallelCtx(), window: Optional[int] = None,
            extra_embeds=None):
    del extra_embeds  # hybrid arch has no modality frontend
    window = cfg.sliding_window if window is None else window
    x = params["embed"][tokens]
    B, S, _ = x.shape
    positions = jnp.arange(S) + pos_offset

    # Group SSM layers between attention applications into scans.
    new_ssm_caches = []
    new_attn_caches = []
    ssm_idx = 0
    groups = []
    g = []
    for i in range(cfg.n_layers):
        if _is_attn_layer(cfg, i):
            groups.append(("ssm", g))
            groups.append(("attn", None))
            g = []
        else:
            g.append(i)
    if g:
        groups.append(("ssm", g))

    attn_i = 0
    for kind, idxs in groups:
        if kind == "ssm":
            if not idxs:
                continue
            n = len(idxs)
            sl = jax.tree.map(lambda a: a[ssm_idx:ssm_idx + n],
                              params["ssm_layers"])
            c = None if caches is None else jax.tree.map(
                lambda a: a[ssm_idx:ssm_idx + n], caches["ssm"])

            def body(h, inp):
                p, cc = inp
                y, nc = mamba2_apply(p["mamba"],
                                     rmsnorm(p["ln"], h, cfg.rms_eps), cfg,
                                     cache=cc)
                return seq_shard(h + y, ctx), nc

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, ncs = jax.lax.scan(body, x, (sl, c))
            if caches is not None:
                new_ssm_caches.append(ncs)
            ssm_idx += n
        else:
            p = params["shared_attn"]
            c = None if caches is None else jax.tree.map(
                lambda a: a[attn_i], caches["attn"])
            h = rmsnorm(p["ln1"], x, cfg.rms_eps)
            a, nc = gqa_apply(p["attn"], h, cfg, positions=positions,
                              cache=c, window=window, ctx=ctx)
            x = x + a
            h = rmsnorm(p["ln2"], x, cfg.rms_eps)
            x = seq_shard(x + mlp_apply(p["mlp"], h, cfg.mlp), ctx)
            if caches is not None:
                new_attn_caches.append(nc)
            attn_i += 1

    x = rmsnorm(params["ln_f"], x, cfg.rms_eps)
    logits = x @ (params["embed"].T if cfg.tie_embeddings
                  else params["unembed"])
    new_caches = None
    if caches is not None:
        new_caches = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                *new_ssm_caches),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *new_attn_caches),
        }
    return logits, new_caches


def loss_fn(cfg: ArchConfig, params, batch, ctx: ParallelCtx = ParallelCtx()):
    from .transformer import xent
    logits, _ = forward(cfg, params, batch["tokens"], ctx=ctx)
    return xent(logits, batch["labels"], ctx)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_c = di + 2 * s.d_state
    ssm_one = {"conv": jnp.zeros((batch, s.d_conv - 1, conv_c), dtype),
               "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state),
                                jnp.float32)}
    hd = cfg.hd()
    if cfg.sliding_window and cfg.sliding_window < max_len:
        W = cfg.sliding_window
        attn_one = {"k": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
                    "pos": jnp.full((W,), -1, jnp.int32),
                    "len": jnp.zeros((), jnp.int32)}
    else:
        attn_one = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
                    "len": jnp.zeros((), jnp.int32)}
    return {
        "ssm": jax.tree.map(lambda x: jnp.stack([x] * _n_ssm(cfg)), ssm_one),
        "attn": jax.tree.map(lambda x: jnp.stack([x] * _n_attn(cfg)), attn_one),
    }


def decode_step(cfg, params, tokens1, caches, pos,
                ctx: ParallelCtx = ParallelCtx()):
    logits, new_caches = forward(cfg, params, tokens1, caches=caches,
                                 pos_offset=pos, ctx=ctx)
    return logits[:, -1], new_caches
