"""Decoder-only LM covering dense / MoE / MLA / VLM-prefix families.

Design notes (these matter for the 512-device dry-run):
  * layers are stacked ([L, ...] leading dim) and iterated with `lax.scan`,
    so the HLO size is O(1) in depth;
  * MoE models with a dense prefix (DeepSeek) use two scans;
  * remat (`jax.checkpoint`) wraps the scan body when cfg.remat;
  * the VLM/audio frontend is a stub: precomputed patch/frame embeddings are
    concatenated in front of the token embeddings (per assignment).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .. import compat
from .config import ArchConfig
from .layers import (gqa_apply, gqa_params, mla_apply, mla_params, mlp_apply,
                     mlp_params, moe_einsum_apply, moe_ep_apply, moe_params,
                     rmsnorm)

PyTree = Any


@dataclass
class ParallelCtx:
    """Parallel execution context for layers needing explicit collectives.

    None mesh => single-device semantics (smoke tests).  When a mesh is
    present, MoE layers with impl='ep_a2a' run inside a shard_map region:
    tokens sharded (dp_spec x 'model' on sequence), experts sharded over
    ``ep_axis``, with explicit all-to-all dispatch (DeepSeek-style EP).
    """
    ep_axis: Optional[str] = None
    ep_size: int = 1
    mesh: Any = None
    dp_spec: Any = None      # PartitionSpec entry for the batch dim


def _stack(key, n: int, init_fn: Callable) -> PyTree:
    keys = jax.random.split(key, n)
    return (jax.vmap(init_fn)(keys) if False else
            jax.tree.map(lambda *xs: jnp.stack(xs), *[init_fn(k) for k in keys]))


def _layer_params(key, cfg: ArchConfig, dtype, moe_layer: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": (mla_params(k1, cfg, dtype) if cfg.mla
                 else gqa_params(k1, cfg, dtype)),
    }
    if moe_layer:
        p["moe"] = moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> PyTree:
    ke, kl, kd, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(cfg.d_model)
    params: dict = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * s
                  ).astype(dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(ko, (cfg.d_model, cfg.vocab))
                             * s).astype(dtype)
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        params["layers"] = _stack(
            kl, n_dense, lambda k: _layer_params(k, cfg, dtype, False))
    if n_moe:
        params["moe_layers"] = _stack(
            kd, n_moe, lambda k: _layer_params(k, cfg, dtype, True))
    return params


def seq_shard(x, ctx: ParallelCtx, enable: bool = True):
    """Sequence-parallel residual: shard S over 'model' between blocks.

    Megatron-SP style — the saved activation per scanned layer becomes
    [B/dp, S/model, d] instead of [B/dp, S, d]; attention/MoE regions gather
    the sequence where they need it (XLA inserts the all-gather).  Disabling
    it (cfg.seq_shard_residual=False) trades ~L x [B,S,d] of extra HBM for
    the removal of the per-layer sequence gathers — the right trade when the
    cell is collective-bound and under the HBM budget (§Perf).
    """
    if ctx is None or ctx.mesh is None or x.ndim != 3:
        return x
    if enable and x.shape[1] % ctx.mesh.shape["model"] == 0:
        return wsc(x, ctx, ctx.dp_spec, "model", None)
    return wsc(x, ctx, ctx.dp_spec, None, None)


def _block(cfg: ArchConfig, p, x, positions, cache, moe_layer: bool,
           ctx: ParallelCtx, window: int = 0):
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    if cfg.mla:
        a, new_cache = mla_apply(p["attn"], h, cfg, positions=positions,
                                 cache=cache, ctx=ctx)
    else:
        a, new_cache = gqa_apply(p["attn"], h, cfg, positions=positions,
                                 cache=cache, window=window, ctx=ctx)
    # §Perf: constrain the row-parallel projection OUTPUT to the SP layout so
    # its partial sums lower to reduce-scatter instead of all-reduce +
    # re-gather (the Megatron-SP identity).
    a = seq_shard(a, ctx, cfg.seq_shard_residual)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if moe_layer:
        f = _moe_dispatch(cfg, p["moe"], h, ctx)
    else:
        f = mlp_apply(p["mlp"], h, cfg.mlp)
    f = seq_shard(f, ctx, cfg.seq_shard_residual)
    return seq_shard(x + f, ctx, cfg.seq_shard_residual), new_cache


def _moe_dispatch(cfg: ArchConfig, pmoe, h, ctx: ParallelCtx):
    """Pick the MoE execution strategy.

    * few tokens (decode) or no mesh: grouped einsum dispatch (pjit shards it)
    * impl='ep_a2a' + mesh: shard_map expert parallelism — tokens sharded
      (batch over dp, sequence over 'model'), experts over 'model', explicit
      all-to-all (the DeepSeek EP pattern).
    """
    B, S, _ = h.shape
    T = B * S
    use_ep = (cfg.moe.impl == "ep_a2a" and ctx.mesh is not None
              and T >= cfg.moe.ep_threshold
              and S % ctx.mesh.shape["model"] == 0)
    if not use_ep:
        if cfg.moe.impl == "ep_a2a" and ctx.mesh is None and T >= 8192:
            # large token count without a mesh: still exercise the EP path
            return moe_ep_apply(pmoe, h, cfg, ep_axis=None, ep_size=1)
        return moe_einsum_apply(pmoe, h, cfg)

    from jax.sharding import PartitionSpec as P
    from ..parallel.sharding import _axis_size, _fit_axis
    # EP spans (data x model) when the expert count divides (DeepSeek: 256
    # experts over the whole 256-chip pod, one expert per chip); otherwise
    # just the model axis.  Must match the storage sharding of the experts.
    ep_axis = _fit_axis(("data", "model"), cfg.moe.n_experts, ctx.mesh)
    if ep_axis is None:
        return moe_einsum_apply(pmoe, h, cfg)
    ep_size = _axis_size(ctx.mesh, ep_axis)
    tok_spec = P(ctx.dp_spec, "model", None)
    routed = {k: v for k, v in pmoe.items() if k != "shared"}
    pspecs = {"router": P(None, None),
              "wg": P(ep_axis, None, None),
              "wu": P(ep_axis, None, None),
              "wd": P(ep_axis, None, None)}

    def region(xx, pp):
        # routed experts only: the shared expert is TP-sharded at pjit level
        # (inside the region its ff-sharded matmul would be a partial sum).
        cfg_routed = cfg.replace(moe=dataclasses.replace(cfg.moe, n_shared=0))
        return moe_ep_apply(pp, xx, cfg_routed, ep_axis=ep_axis,
                            ep_size=ep_size)

    out = compat.shard_map(region, mesh=ctx.mesh,
                           in_specs=(tok_spec, pspecs),
                           out_specs=tok_spec)(h, routed)
    if cfg.moe.n_shared:
        out = out + mlp_apply(pmoe["shared"], h, "swiglu")
    return out


def _scan_blocks(cfg: ArchConfig, stacked, x, positions, caches, moe: bool,
                 ctx: ParallelCtx, window: int = 0):
    """lax.scan over the stacked layer params (cache is scanned along L)."""

    def body(carry, inp):
        x = carry
        p, cache = inp
        x, new_cache = _block(cfg, p, x, positions, cache, moe, ctx, window)
        return x, new_cache

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


def _unrolled_blocks(cfg, stacked, x, positions, caches, moe, ctx, window=0):
    n = jax.tree.leaves(stacked)[0].shape[0]
    new_caches = []
    for i in range(n):
        p = jax.tree.map(lambda a: a[i], stacked)
        c = None if caches is None else jax.tree.map(lambda a: a[i], caches)
        blk = partial(_block, cfg)
        if cfg.remat:
            blk = jax.checkpoint(blk, static_argnums=(5, 6, 7))
        x, nc = _block(cfg, p, x, positions, c, moe, ctx, window)
        new_caches.append(nc)
    if caches is None:
        return x, None
    return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)


def wsc(x, ctx: ParallelCtx, *spec):
    """with_sharding_constraint when a mesh is present (no-op otherwise)."""
    if ctx is None or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def _embed(cfg: ArchConfig, params, tokens, extra_embeds=None):
    x = params["embed"][tokens]
    if extra_embeds is not None:
        # VLM/audio stub: prefix precomputed embeddings
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _unembed_mm(x, w, ctx, transpose_w):
    return x @ (w.T if transpose_w else w)


def _unembed_fwd(x, w, ctx, transpose_w):
    return _unembed_mm(x, w, ctx, transpose_w), (x, w)


def _unembed_bwd(ctx, transpose_w, res, g):
    """§Perf (iteration 9): the default VJP materializes a FULL unsharded f32
    [d, V] weight gradient per device (~3.7 GB x3 at deepseek scale).  Here
    the cotangent is cast to bf16 (MXU still accumulates f32 internally) and
    the weight grad is sharding-constrained to the weight's own layout, so
    the partial sums reduce-scatter instead of replicating."""
    x, w = res
    gb = g.astype(w.dtype)
    dx = (gb @ (w if transpose_w else w.T)).astype(x.dtype)
    d_flat = x.reshape(-1, x.shape[-1])
    g_flat = gb.reshape(-1, gb.shape[-1])
    dw = jax.lax.dot_general(d_flat, g_flat, (((0,), (0,)), ((), ())),
                             preferred_element_type=w.dtype)  # [d, V]
    if transpose_w:
        dw = dw.T                                             # [V, d]
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        model_n = ctx.mesh.shape["model"]
        if transpose_w:   # tied embedding [V, d]
            spec = P("model" if w.shape[0] % model_n == 0 else None, None)
        else:             # unembed [d, V]
            spec = P(None, "model" if w.shape[1] % model_n == 0 else None)
        dw = jax.lax.with_sharding_constraint(
            dw, NamedSharding(ctx.mesh, spec))
    return dx, dw.astype(w.dtype)


_unembed_mm.defvjp(_unembed_fwd, _unembed_bwd)


def _unembed(cfg: ArchConfig, params, x, ctx: ParallelCtx = None):
    if cfg.tie_embeddings:
        return _unembed_mm(x, params["embed"], ctx, True)
    return _unembed_mm(x, params["unembed"], ctx, False)


def forward(cfg: ArchConfig, params, tokens, *, extra_embeds=None,
            caches=None, pos_offset=0, ctx: ParallelCtx = ParallelCtx(),
            window: Optional[int] = None):
    """Full forward pass. tokens [B,S] -> logits [B,S_total,V].

    caches: per-family cache pytree (see ``init_cache``) for incremental
    decoding; pos_offset is the absolute position of tokens[:,0].
    """
    window = cfg.sliding_window if window is None else window
    x = _embed(cfg, params, tokens, extra_embeds)
    if x.shape[1] > 1:
        x = wsc(x, ctx, ctx.dp_spec, None, None)
    S = x.shape[1]
    positions = jnp.arange(S) + pos_offset
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.n_layers - n_moe
    new_caches = {}
    run = _scan_blocks if cfg.scan_layers else _unrolled_blocks
    if n_dense:
        c = caches.get("dense") if caches else None
        x, nc = run(cfg, params["layers"], x, positions, c, False, ctx, window)
        new_caches["dense"] = nc
    if n_moe:
        c = caches.get("moe") if caches else None
        x, nc = run(cfg, params["moe_layers"], x, positions, c, True, ctx, window)
        new_caches["moe"] = nc
    x = rmsnorm(params["ln_f"], x, cfg.rms_eps)
    logits = _unembed(cfg, params, x, ctx)
    return (logits, new_caches if caches is not None else None)


def xent(logits, labels, ctx: ParallelCtx = ParallelCtx()):
    """Sharded cross entropy that never materializes unsharded f32 logits.

    Preferred layout: sequence-sharded logits (dp, 'model', None) — every
    reduction is vocab-local, gradients stay sharded, and the only extra
    collective is the small unembed-wgrad all-reduce.  Falls back to
    vocab-sharded (dp, None, 'model') when S doesn't divide the model axis.
    The gold logit is a one-hot *contraction*, not a gather: SPMD partitions
    the fused compare-select-reduce without an all-gather (a gather along a
    sharded vocab axis would re-materialize [B,S,V] f32 per device).
    """
    if ctx is not None and ctx.mesh is not None:
        if logits.shape[1] % ctx.mesh.shape["model"] == 0:
            logits = wsc(logits, ctx, ctx.dp_spec, "model", None)
        else:
            logits = wsc(logits, ctx, ctx.dp_spec, None, "model")
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", lf, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ArchConfig, params, batch, ctx: ParallelCtx = ParallelCtx()):
    """Next-token cross-entropy; batch = {tokens, labels[, extra_embeds]}."""
    logits, _ = forward(cfg, params, batch["tokens"],
                        extra_embeds=batch.get("extra_embeds"), ctx=ctx)
    labels = batch["labels"]
    if batch.get("extra_embeds") is not None:
        # loss only on text positions: pad labels with -1 over the modality
        # prefix instead of slicing logits (slicing a sequence-sharded logits
        # tensor would force an unsharded materialization).
        prefix = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full(labels.shape[:1] + (prefix,), -1, labels.dtype),
             labels], axis=1)
    return xent(logits, labels, ctx)


# ----------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked per-layer decode caches."""
    def one(kind: str):
        if cfg.mla:
            m = cfg.mla
            return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                                        dtype),
                    "len": jnp.zeros((), jnp.int32)}
        hd = cfg.hd()
        if cfg.sliding_window and cfg.sliding_window < max_len:
            W = cfg.sliding_window
            return {"k": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
                    "pos": jnp.full((W,), -1, jnp.int32),
                    "len": jnp.zeros((), jnp.int32)}
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
                "len": jnp.zeros((), jnp.int32)}

    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.n_layers - n_moe
    out = {}
    if n_dense:
        out["dense"] = jax.tree.map(
            lambda x: jnp.stack([x] * n_dense), one("dense"))
    if n_moe:
        out["moe"] = jax.tree.map(
            lambda x: jnp.stack([x] * n_moe), one("moe"))
    return out


def decode_step(cfg: ArchConfig, params, tokens1, caches, pos,
                ctx: ParallelCtx = ParallelCtx()):
    """One incremental decode step: tokens1 [B,1] at absolute position pos."""
    logits, new_caches = forward(cfg, params, tokens1, caches=caches,
                                 pos_offset=pos, ctx=ctx)
    return logits[:, -1], new_caches
