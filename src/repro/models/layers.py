"""Core layers: RMSNorm, RoPE, chunked (flash-style) attention, GQA, MLA,
SwiGLU/GELU MLPs, and MoE (einsum dispatch + expert-parallel all-to-all).

All layers are pure functions over pytree params.  Attention uses an
online-softmax chunked algorithm in plain lax (same algorithm as the Pallas
kernel in ``repro.kernels.flash_attention``), so the 32k-sequence shapes never
materialize an S×S score matrix even on the XLA path.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ArchConfig

NEG_INF = -1e30


# ------------------------------------------------------------------- basics
def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (w * x).astype(dt)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding. x: [..., S, H, D], pos: [S] or [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs        # [.., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [.., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# -------------------------------------------------- chunked flash attention
def _attn_chunked(q, k, v, *, causal: bool, q_pos, kv_pos,
                  window: int = 0, chunk: int = 1024, q_block: int = 512,
                  scale: float = None):
    """Online-softmax attention, blocked on BOTH q and kv (flash algorithm).

    q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D]; GQA by head grouping.
    Peak live memory is O(q_block * chunk) per (batch, head) — both loops are
    rematerialized in the backward pass (flash backward), so no O(Sq*Skv)
    tensor is ever saved.
    """
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale or (1.0 / math.sqrt(D))

    q_block = min(q_block, Sq)
    qpad = (-Sq) % q_block
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, qpad), constant_values=2_000_000_000)
    nqb = (Sq + qpad) // q_block
    qg = q.reshape(B, nqb, q_block, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nqb, q_block)

    nchunk = (Skv + chunk - 1) // chunk
    pad = nchunk * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1_000_000_000)
    kc = k.reshape(B, nchunk, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nchunk, chunk)

    def make_q_body(kc_g, vc_g, pc_g):
        def q_body(qb_and_pos):
            qb, pb_q = qb_and_pos

            def body(carry, inp):
                m, lsum, acc = carry
                kb, vb, pb = inp
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
                mask = jnp.ones((q_block, chunk), dtype=bool)
                if causal:
                    mask &= pb_q[:, None] >= pb[None, :]
                if window:
                    mask &= pb_q[:, None] - pb[None, :] < window
                mask &= pb[None, :] > -1_000_000_000 + 1  # kv padding
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = lsum * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
            a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
            (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                             (kc_g, vc_g, pc_g))
            out = acc / jnp.maximum(lsum, 1e-30)[..., None]
            return out.astype(q.dtype)        # [B,Hkv,G,q_block,Dv]
        return jax.checkpoint(
            q_body, policy=jax.checkpoint_policies.nothing_saveable)

    # §Perf: static causal split — group the q blocks and give each group
    # only the kv chunks at or below its causal horizon.  With 4 groups the
    # fully-masked upper-triangle block work drops ~37.5% while every loop
    # keeps a STATIC trip count (dynamic bounds would break both Mosaic
    # pipelining on TPU and the HLO cost accounting).
    n_groups = 4 if (causal and not window and nqb >= 8) else 1
    per = nqb // n_groups
    outs_groups = []
    for gi in range(n_groups):
        lo = gi * per
        hi = nqb if gi == n_groups - 1 else (gi + 1) * per
        n_ch = nchunk if gi == n_groups - 1 else min(nchunk, -(-(hi * q_block) // chunk))
        q_body = make_q_body(kc[:n_ch], vc[:n_ch], pc[:n_ch])
        outs_groups.append(jax.lax.map(q_body, (qg[lo:hi], qp[lo:hi])))
    outs = (jnp.concatenate(outs_groups, axis=0) if n_groups > 1
            else outs_groups[0])               # [nqb,B,Hkv,G,q_block,Dv]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(
        B, Sq + qpad, Hkv * G, Dv)
    if qpad:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def _attn_direct(q, k, v, *, causal, q_pos, kv_pos, window=0, scale=None):
    """Direct attention (decode / small sequences)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale or (1.0 / math.sqrt(D))
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    mask &= kv_pos[None, :] >= 0
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    Dv = v.shape[-1]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def attention_core(q, k, v, *, causal=True, q_pos=None, kv_pos=None,
                   window=0, scale=None, impl="xla"):
    Sq, Skv = q.shape[1], k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if kv_pos is None:
        kv_pos = jnp.arange(Skv)
    if impl == "pallas":
        from ..kernels import ops as kops
        if Sq == Skv and causal and window == 0 and Sq % 128 == 0:
            return kops.flash_attention(q, k, v, causal=True)
        # fall through for shapes the kernel doesn't cover
    if Sq == 1 or Sq * Skv <= 1024 * 1024:
        return _attn_direct(q, k, v, causal=causal, q_pos=q_pos,
                            kv_pos=kv_pos, window=window, scale=scale)
    return _attn_chunked(q, k, v, causal=causal, q_pos=q_pos, kv_pos=kv_pos,
                         window=window, scale=scale)


# ---------------------------------------------------------------------- GQA
def gqa_params(key, cfg: ArchConfig, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, Hkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, Hkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H * hd, d)) * s).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def gqa_apply(p, x, cfg: ArchConfig, *, positions, cache=None,
              causal=True, window=0, ctx=None):
    """GQA attention.  cache: dict(k,v [B,Smax,Hkv,hd], len) for decode."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if ctx is not None and getattr(ctx, "mesh", None) is not None and S > 1:
        # §Perf: materialize K/V with a FIXED batch-only sharding before the
        # flash q-block/kv-chunk loops.  Without this, the sequence-sharded
        # K/V is re-all-gathered inside every loop iteration (nqb x nchunk x
        # L x remat times); with it, SPMD gathers once per layer.
        from .transformer import wsc
        hkv_ax = "model" if Hkv % ctx.mesh.shape["model"] == 0 else None
        q = wsc(q, ctx, ctx.dp_spec, None, "model"
                if H % ctx.mesh.shape["model"] == 0 else None, None)
        k = wsc(k, ctx, ctx.dp_spec, None, hkv_ax, None)
        v = wsc(v, ctx, ctx.dp_spec, None, hkv_ax, None)
    new_cache = None
    if cache is not None:
        if "pos" in cache:
            # ring buffer (sliding-window long-context decode): S must be 1
            W = cache["k"].shape[1]
            idx = cache["len"] % W
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
            cp = jax.lax.dynamic_update_slice(
                cache["pos"], positions.astype(jnp.int32), (idx,))
            new_cache = {"k": ck, "v": cv, "pos": cp, "len": cache["len"] + S}
            out = attention_core(q, ck, cv, causal=causal, q_pos=positions,
                                 kv_pos=cp, window=window, impl=cfg.attn_impl)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k,
                                              (0, cache["len"], 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v,
                                              (0, cache["len"], 0, 0))
            new_cache = {"k": ck, "v": cv, "len": cache["len"] + S}
            kv_pos = jnp.arange(ck.shape[1])
            kv_pos = jnp.where(kv_pos < cache["len"] + S, kv_pos, -1)
            out = attention_core(q, ck, cv, causal=causal, q_pos=positions,
                                 kv_pos=kv_pos, window=window,
                                 impl=cfg.attn_impl)
    else:
        out = attention_core(q, k, v, causal=causal, q_pos=positions,
                             kv_pos=positions, window=window,
                             impl=cfg.attn_impl)
    return out.reshape(B, S, H * hd) @ p["wo"], new_cache


# ---------------------------------------------------------------------- MLA
def mla_params(key, cfg: ArchConfig, dtype):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * s).astype(dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wuq": (jax.random.normal(ks[1], (m.q_lora_rank, H * qk_dim))
                / math.sqrt(m.q_lora_rank)).astype(dtype),
        "wdkv": (jax.random.normal(ks[2], (d, m.kv_lora_rank)) * s).astype(dtype),
        "wkr": (jax.random.normal(ks[3], (d, m.qk_rope_head_dim)) * s).astype(dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wuk": (jax.random.normal(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim))
                / math.sqrt(m.kv_lora_rank)).astype(dtype),
        "wuv": (jax.random.normal(ks[5], (m.kv_lora_rank, H * m.v_head_dim))
                / math.sqrt(m.kv_lora_rank)).astype(dtype),
        "wo": (jax.random.normal(ks[6], (H * m.v_head_dim, d)) * s).astype(dtype),
    }


def mla_apply(p, x, cfg: ArchConfig, *, positions, cache=None,
              absorbed_decode: bool = True, ctx=None):
    """DeepSeek MLA.  The decode cache stores only (c_kv, k_rope) —
    (kv_lora_rank + rope_dim) per token instead of 2·H·hd.

    absorbed_decode: use the W_uk-absorption identity so decode attends
    directly against the compressed cache (never materializes K for the
    whole context) — a §Perf optimization, default-on.
    """
    m, H = cfg.mla, cfg.n_heads
    B, S, d = x.shape
    nope, rdim, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cq = rmsnorm(p["q_norm"], x @ p["wdq"], cfg.rms_eps)
    q = (cq @ p["wuq"]).reshape(B, S, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["wdkv"]                                # [B,S,r]
    k_rope = rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_theta)
    c_kv_n = rmsnorm(p["kv_norm"], c_kv, cfg.rms_eps)

    scale = 1.0 / math.sqrt(nope + rdim)
    if cache is not None:
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_n,
                                          (0, cache["len"], 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope[:, :, 0, :],
                                          (0, cache["len"], 0))
        new_cache = {"c_kv": cc, "k_rope": cr, "len": cache["len"] + S}
        Sk = cc.shape[1]
        kv_pos = jnp.arange(Sk)
        kv_pos_m = jnp.where(kv_pos < cache["len"] + S, kv_pos, -1)
        if absorbed_decode:
            # q_c[h] = W_uk[h]^T q_nope[h]  -> score = q_c . c_kv + q_r . k_r
            wuk = p["wuk"].reshape(m.kv_lora_rank, H, nope)
            q_c = jnp.einsum("bshn,rhn->bshr", q_nope, wuk)
            s1 = jnp.einsum("bshr,bkr->bhsk", q_c, cc,
                            preferred_element_type=jnp.float32)
            s2 = jnp.einsum("bshr,bkr->bhsk", q_rope, cr,
                            preferred_element_type=jnp.float32)
            sc = (s1 + s2) * scale
            mask = (positions[:, None] >= kv_pos_m[None, :]) & (kv_pos_m >= 0)[None, :]
            sc = jnp.where(mask[None, None], sc, NEG_INF)
            pr = jax.nn.softmax(sc, axis=-1)
            # out[h] = (pr . c_kv) W_uv[h]
            ctx = jnp.einsum("bhsk,bkr->bshr", pr.astype(cc.dtype), cc)
            wuv = p["wuv"].reshape(m.kv_lora_rank, H, vdim)
            out = jnp.einsum("bshr,rhv->bshv", ctx, wuv)
        else:
            k_nope = (cc @ p["wuk"]).reshape(B, Sk, H, nope)
            vfull = (cc @ p["wuv"]).reshape(B, Sk, H, vdim)
            kfull = jnp.concatenate(
                [k_nope, jnp.broadcast_to(cr[:, :, None, :], (B, Sk, H, rdim))],
                axis=-1)
            qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = attention_core(qfull, kfull, vfull, causal=True,
                                 q_pos=positions, kv_pos=kv_pos_m, scale=scale)
        return out.reshape(B, S, H * vdim) @ p["wo"], new_cache

    k_nope = (c_kv_n @ p["wuk"]).reshape(B, S, H, nope)
    vfull = (c_kv_n @ p["wuv"]).reshape(B, S, H, vdim)
    kfull = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rdim))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    if ctx is not None and getattr(ctx, "mesh", None) is not None and S > 1:
        # §Perf: fix Q/K/V sharding (heads over 'model') before the flash
        # loops — otherwise the seq-sharded K/V is re-gathered per q-block.
        from .transformer import wsc
        hax = "model" if H % ctx.mesh.shape["model"] == 0 else None
        qfull = wsc(qfull, ctx, ctx.dp_spec, None, hax, None)
        kfull = wsc(kfull, ctx, ctx.dp_spec, None, hax, None)
        vfull = wsc(vfull, ctx, ctx.dp_spec, None, hax, None)
    out = attention_core(qfull, kfull, vfull, causal=True, q_pos=positions,
                         kv_pos=positions, scale=scale, impl=cfg.attn_impl)
    return out.reshape(B, S, H * vdim) @ p["wo"], None


# ---------------------------------------------------------------------- MLP
def mlp_params(key, d: int, ff: int, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    if kind == "swiglu":
        return {"wg": (jax.random.normal(k1, (d, ff)) * s).astype(dtype),
                "wu": (jax.random.normal(k2, (d, ff)) * s).astype(dtype),
                "wd": (jax.random.normal(k3, (ff, d)) / math.sqrt(ff)).astype(dtype)}
    return {"w1": (jax.random.normal(k1, (d, ff)) * s).astype(dtype),
            "w2": (jax.random.normal(k2, (ff, d)) / math.sqrt(ff)).astype(dtype)}


def mlp_apply(p, x, kind: str):
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


# ---------------------------------------------------------------------- MoE
def moe_params(key, cfg: ArchConfig, dtype):
    mo, d = cfg.moe, cfg.d_model
    ff = mo.d_ff_expert
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, mo.n_experts)) * s).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (mo.n_experts, d, ff)) * s).astype(dtype),
        "wu": (jax.random.normal(ks[2], (mo.n_experts, d, ff)) * s).astype(dtype),
        "wd": (jax.random.normal(ks[3], (mo.n_experts, ff, d)) / math.sqrt(ff)).astype(dtype),
    }
    if mo.n_shared:
        p["shared"] = mlp_params(ks[4], d, ff * mo.n_shared, "swiglu", dtype)
    return p


def moe_einsum_apply(p, x, cfg: ArchConfig):
    """Switch-style capacity dispatch with *grouped* one-hot einsums.

    The dispatch tensor is [G, Tg, E, C] with C per-group: total memory is
    T·E·C/G = T·Tg·k·cf — bounded by the group size, not the global batch,
    so the formulation stays viable at 1M tokens.  Groups align with the
    batch sharding, so dispatch einsums never cross shards.
    """
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    Tg = min(getattr(mo, "group_size", 512), T)
    G = T // Tg
    if G * Tg != T:  # fall back to a single group for ragged tiny inputs
        G, Tg = 1, T
    xt = x.reshape(G, Tg, d)
    logits = xt.astype(jnp.float32) @ p["router"]           # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, mo.top_k)              # [G,Tg,k]
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9))
    C = max(1, int(Tg * mo.top_k / mo.n_experts * mo.capacity_factor))
    onehot = jax.nn.one_hot(idx, mo.n_experts, dtype=jnp.int32)  # [G,Tg,k,E]
    pos_all = (jnp.cumsum(onehot.reshape(G, Tg * mo.top_k, mo.n_experts),
                          axis=1).reshape(G, Tg, mo.top_k, mo.n_experts) - 1)
    pos = (pos_all * onehot).sum(-1)                        # [G,Tg,k]
    keep = pos < C
    slot_oh = (jax.nn.one_hot(pos, C, dtype=x.dtype)
               * keep[..., None].astype(x.dtype))           # [G,Tg,k,C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), slot_oh)
    comb = jnp.einsum("gtke,gtk,gtkc->gtec", onehot.astype(jnp.float32),
                      gate.astype(jnp.float32), slot_oh.astype(jnp.float32))
    xe = jnp.einsum("gtd,gtec->gecd", xt, disp)             # [G,E,C,d]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["wu"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    yt = jnp.einsum("gecd,gtec->gtd", ye, comb.astype(x.dtype))
    out = yt.reshape(B, S, d)
    if mo.n_shared:
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out


MOE_PARAM_SPECS = {
    "router": P(None, None),
    "wg": P("model", None, None),
    "wu": P("model", None, None),
    "wd": P("model", None, None),
    "shared": {"wg": P(None, "model"), "wu": P(None, "model"),
               "wd": P("model", None)},
}


def moe_ep_apply(p, x, cfg: ArchConfig, *, ep_axis: Optional[str] = None,
                 ep_size: int = 1):
    """Expert-parallel MoE with explicit all-to-all (DeepSeek-style EP).

    Called inside shard_map: ``x`` is the per-device token block
    [B_loc, S_loc, d]; expert weights arrive sliced [E_loc, ...] where
    E_loc = E / ep_size.  Dispatch: local top-k -> sort by destination
    shard -> fixed-capacity send buffer -> all_to_all -> local expert
    GEMMs -> all_to_all back -> weighted combine.
    """
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E = mo.n_experts
    e_loc = E // ep_size
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, mo.top_k)              # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    TK = T * mo.top_k
    flat_e = idx.reshape(TK)                                # expert id per slot
    flat_dst = flat_e // e_loc                              # destination shard
    flat_tok = jnp.repeat(jnp.arange(T), mo.top_k)
    flat_gate = gate.reshape(TK)

    # capacity per destination shard
    C = max(1, int(TK / ep_size * mo.capacity_factor))
    order = jnp.argsort(flat_dst)                           # local sort (cheap)
    e_sorted = flat_e[order]
    d_sorted = flat_dst[order]
    t_sorted = flat_tok[order]
    g_sorted = flat_gate[order]
    # position within destination bucket
    pos_in_dst = jnp.arange(TK) - jnp.searchsorted(d_sorted, d_sorted, side="left")
    keep = pos_in_dst < C
    slot = jnp.where(keep, d_sorted * C + pos_in_dst, ep_size * C)  # overflow->drop

    send_x = jnp.zeros((ep_size * C + 1, d), x.dtype).at[slot].set(xt[t_sorted])
    send_e = jnp.full((ep_size * C + 1,), -1, jnp.int32).at[slot].set(
        (e_sorted % e_loc).astype(jnp.int32))
    send_x, send_e = send_x[:-1], send_e[:-1]

    if ep_axis is not None:
        recv_x = jax.lax.all_to_all(send_x.reshape(ep_size, C, d), ep_axis,
                                    0, 0, tiled=False).reshape(ep_size * C, d)
        recv_e = jax.lax.all_to_all(send_e.reshape(ep_size, C), ep_axis,
                                    0, 0, tiled=False).reshape(ep_size * C)
    else:
        recv_x, recv_e = send_x, send_e

    # local expert processing: sort received slots by local expert id
    N = recv_x.shape[0]
    Ce = max(1, int(N / e_loc * mo.capacity_factor))
    ekey_raw = jnp.where(recv_e < 0, e_loc, recv_e)   # empty slots sort last
    order2 = jnp.argsort(ekey_raw)
    ekey = ekey_raw[order2]                            # sorted
    pos2 = jnp.arange(N) - jnp.searchsorted(ekey, ekey, side="left")
    keep2 = (pos2 < Ce) & (ekey < e_loc)
    slot2 = jnp.where(keep2, ekey * Ce + pos2, e_loc * Ce)
    buf = jnp.zeros((e_loc * Ce + 1, d), x.dtype).at[slot2].set(recv_x[order2])
    buf = buf[:-1].reshape(e_loc, Ce, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wu"])
    yb = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(e_loc * Ce, d)

    # un-sort back to recv slot order, then all_to_all back
    y_recv = jnp.zeros((N, d), x.dtype)
    take = jnp.where(keep2, slot2, 0)
    vals = jnp.where(keep2[:, None], yb[take], 0)
    y_recv = y_recv.at[order2].set(vals)

    if ep_axis is not None:
        y_send = jax.lax.all_to_all(y_recv.reshape(ep_size, C, d), ep_axis,
                                    0, 0, tiled=False).reshape(ep_size * C, d)
    else:
        y_send = y_recv

    # combine at origin: slot -> (token, gate)
    contrib = jnp.where(keep[:, None], y_send[jnp.where(keep, slot, 0)], 0)
    yt = jnp.zeros((T, d), jnp.float32).at[t_sorted].add(
        contrib.astype(jnp.float32) * g_sorted[:, None])
    out = yt.astype(x.dtype).reshape(B, S, d)
    if mo.n_shared:
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out
