"""Architecture configuration — one dataclass covering the 10 assigned archs.

Families: dense decoder (GQA), MoE (top-k routed + shared), MLA (DeepSeek
low-rank attention), hybrid SSM (Mamba2 + shared attention), pure SSM
(RWKV6), encoder-decoder (Whisper backbone), VLM backbone (LM + patch-embed
prefix stub).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    impl: str = "einsum"         # 'einsum' (small E) | 'ep_a2a' (shard_map EP)
    group_size: int = 512        # einsum dispatch group (tokens)
    ep_threshold: int = 4096     # below this many tokens, use einsum anyway


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256             # SSD chunk length


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None     # default d_model // n_heads
    qkv_bias: bool = False             # Qwen2-style
    mlp: str = "swiglu"                # swiglu | gelu
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0            # MoE models: leading dense layers
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # hybrid (Zamba2): one shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # encoder-decoder (Whisper backbone)
    encdec: bool = False
    n_encoder_layers: int = 0

    # VLM / audio frontends are stubs: inputs arrive as precomputed embeddings
    frontend: str = "none"             # none | patch_stub | frame_stub
    frontend_seq: int = 0              # prefix length supplied by the stub

    # long-context attention policy: 0 = full causal; >0 = sliding window
    sliding_window: int = 0

    # training-time policy knobs (overridable per run)
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "xla"             # xla | pallas
    seq_shard_residual: bool = True    # Megatron-SP residual (memory vs comm)

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.rwkv is not None

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path available (SSM / hybrid-with-window)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd = self.hd()
        for i in range(self.n_layers):
            if self.family == "ssm" and self.rwkv is not None:
                di = d * 2
                tm = d * di * 2 + di * d + (self.rwkv.decay_lora * d * 2) * 2
                cm = d * self.d_ff + self.d_ff * d
                total += tm + cm
                continue
            is_ssm_layer = (self.ssm is not None and
                            not (self.shared_attn_every and
                                 (i + 1) % self.shared_attn_every == 0))
            if is_ssm_layer and self.family == "hybrid":
                di = self.ssm.expand * d
                nheads = di // self.ssm.head_dim
                total += d * (2 * di + 2 * self.ssm.d_state + nheads) + di * d
            else:
                if self.mla is not None:
                    m = self.mla
                    total += (d * m.q_lora_rank
                              + m.q_lora_rank * self.n_heads
                              * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * self.n_heads
                              * (m.qk_nope_head_dim + m.v_head_dim)
                              + self.n_heads * m.v_head_dim * d)
                else:
                    total += (d * (self.n_heads * hd)
                              + 2 * d * (self.n_kv_heads * hd)
                              + (self.n_heads * hd) * d)
            if self.moe is not None and i >= self.n_dense_layers and not is_ssm_layer:
                ff = self.moe.d_ff_expert
                per = (3 if self.mlp == "swiglu" else 2) * d * ff
                total += per * (self.moe.n_experts + self.moe.n_shared)
                total += d * self.moe.n_experts  # router
            elif not is_ssm_layer or self.family != "hybrid":
                total += (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
        if self.encdec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.n_encoder_layers * (
                4 * d * d + (3 if self.mlp == "swiglu" else 2) * d * self.d_ff)
            cross = self.n_layers * 4 * d * d
            total += enc + cross
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        ff = self.moe.d_ff_expert
        per = (3 if self.mlp == "swiglu" else 2) * self.d_model * ff
        n_moe_layers = self.n_layers - self.n_dense_layers
        unused = per * (self.moe.n_experts - self.moe.top_k) * n_moe_layers
        return full - unused

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke_config(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            frontend_seq=8 if self.frontend != "none" else 0,
        )
        if self.moe is not None:
            # drop-free capacity so prefill/decode consistency is exact
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1), capacity_factor=4.0)
            kw["n_dense_layers"] = min(self.n_dense_layers, 1)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk=8)
            kw["n_layers"] = min(self.n_layers, 4)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
            kw["n_layers"] = 4
        if self.encdec:
            kw["n_encoder_layers"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 16
        return self.replace(**kw)
