"""Lexicographic scanning of integer points — the paper's generated loops.

The paper's codegen (§4) turns dependence polyhedra into loop nests that scan
predecessors/successors of a task (get/put/autodec loops).  Here a
:class:`LoopNest` plays that role: it precomputes, per loop level, the
Fourier-Motzkin projection of the polyhedron onto the outer dims, so that at
"run time" (task execution) each level's bounds are cheap affine min/max
evaluations — exactly like generated C loop bounds.

Scanning is exact over the integers: level-k bounds come from the rational
projection, and integer-empty inner ranges simply produce empty loops.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction
from typing import Iterator, Optional, Sequence

from .polyhedron import Polyhedron
from .projection import project_out

F0 = Fraction(0)


@dataclass
class _Level:
    """Bounds for one loop dim: rows over [outer dims..., this dim, params, 1].

    The level-k system has k+1 dims (outer dims + this one); parameters start
    at column k+1.
    """
    lowers: list[tuple]   # a_k > 0 rows: d_k >= ceil(-(rest)/a_k)
    uppers: list[tuple]   # a_k < 0 rows: d_k <= floor(rest/(-a_k))
    k: int

    @property
    def param_off(self) -> int:
        return self.k + 1


class LoopNest:
    """Scan the integer points of ``poly`` in lexicographic dim order."""

    def __init__(self, poly: Polyhedron, simplify: str = "auto"):
        self.poly = poly.canonical()
        self.ndim = poly.ndim
        self.nparam = poly.nparam
        self.levels: list[_Level] = []
        self._infeasible = False
        # guards: rows with no dim support (pure parameter constraints);
        # they surface in the outermost projected system and must be checked
        # at evaluation time or infeasible parameter values scan garbage.
        self._guards: list[tuple] = []
        cur = self.poly
        systems = [None] * self.ndim
        for k in range(self.ndim - 1, -1, -1):
            systems[k] = cur
            if k > 0:
                cur = project_out(cur, [k], simplify=simplify)
        if self.ndim == 0:
            self._guards = list(self.poly.all_rows_as_ineqs())
            return
        for k in range(self.ndim):
            sys_k = systems[k]
            rows = sys_k.all_rows_as_ineqs()
            lowers, uppers = [], []
            for r in rows:
                c = r[k]
                if c > 0:
                    lowers.append(r)
                elif c < 0:
                    uppers.append(r)
                elif k == 0:
                    # pure-parameter guard (dim coeff 0 in the 1-dim system)
                    if all(x == 0 for x in r[:-1]):
                        if r[-1] < 0:
                            self._infeasible = True
                    else:
                        self._guards.append(r)
            self.levels.append(_Level(lowers, uppers, k))

    def feasible(self, params) -> bool:
        """Evaluate the pure-parameter guards."""
        if self._infeasible:
            return False
        pv = self._param_vec(params)
        off = 1 if self.ndim else 0
        for r in self._guards:
            v = r[-1]
            for j in range(self.nparam):
                v += r[off + j] * pv[j]
            if v < 0:
                return False
        return True

    # ------------------------------------------------------------------ eval
    def _bounds(self, level: _Level, prefix: list[int],
                params: Sequence[int]) -> tuple[Optional[int], Optional[int]]:
        """Integer [lb, ub] for dim k given outer values; None = unbounded."""
        k = level.k
        off = level.param_off
        lb: Optional[int] = None
        ub: Optional[int] = None
        for r in level.lowers:
            a = r[k]
            rest = r[-1]
            for j in range(k):
                rest += r[j] * prefix[j]
            for j in range(self.nparam):
                rest += r[off + j] * params[j]
            v = math.ceil(Fraction(-rest, 1) / a)
            lb = v if lb is None else max(lb, v)
        for r in level.uppers:
            a = -r[k]
            rest = r[-1]
            for j in range(k):
                rest += r[j] * prefix[j]
            for j in range(self.nparam):
                rest += r[off + j] * params[j]
            v = math.floor(Fraction(rest, 1) / a)
            ub = v if ub is None else min(ub, v)
        return lb, ub

    def iterate(self, params: dict[str, int] | Sequence[int] = ()) -> Iterator[tuple[int, ...]]:
        """Yield every integer point (requires bounded dims)."""
        if not self.feasible(params):
            return
        pv = self._param_vec(params)
        if self.ndim == 0:
            yield ()
            return
        yield from self._rec(0, [], pv)

    def _rec(self, k: int, prefix: list[int], pv) -> Iterator[tuple[int, ...]]:
        if k == self.ndim:
            yield tuple(prefix)
            return
        lb, ub = self._bounds(self.levels[k], prefix, pv)
        if lb is None or ub is None:
            raise ValueError(f"dim {k} ({self.poly.dim_names[k]}) is unbounded")
        for v in range(lb, ub + 1):
            prefix.append(v)
            yield from self._rec(k + 1, prefix, pv)
            prefix.pop()

    def count(self, params: dict[str, int] | Sequence[int] = ()) -> int:
        """Number of integer points (innermost level counted closed-form)."""
        if not self.feasible(params):
            return 0
        pv = self._param_vec(params)
        if self.ndim == 0:
            return 1
        return self._count_rec(0, [], pv)

    def _count_rec(self, k: int, prefix: list[int], pv) -> int:
        lb, ub = self._bounds(self.levels[k], prefix, pv)
        if lb is None or ub is None:
            raise ValueError(f"dim {k} is unbounded; cannot count")
        if ub < lb:
            return 0
        if k == self.ndim - 1:
            return ub - lb + 1
        total = 0
        for v in range(lb, ub + 1):
            prefix.append(v)
            total += self._count_rec(k + 1, prefix, pv)
            prefix.pop()
        return total

    def first(self, params=()) -> Optional[tuple[int, ...]]:
        return next(self.iterate(params), None)

    def is_empty_at(self, params=()) -> bool:
        return self.first(params) is None

    # ------------------------------------------------------------- structure
    def is_rectangular(self) -> bool:
        """True if every level's bounds are independent of outer dims.

        This is the shape heuristic of §4.3: rectangular nests admit an O(n)
        closed-form enumerator; ragged ones are counted by scanning.
        """
        for level in self.levels:
            for r in level.lowers + level.uppers:
                if any(r[j] != 0 for j in range(level.k)):
                    return False
        return True

    def _param_vec(self, params) -> list[int]:
        if isinstance(params, dict):
            return [params[n] for n in self.poly.param_names]
        pv = list(params)
        assert len(pv) == self.nparam, \
            f"expected {self.nparam} params {self.poly.param_names}, got {pv}"
        return pv

    # ---------------------------------------------------------------- codegen
    def pretty_loops(self) -> str:
        """Human-readable pseudo-C of the generated loop nest (docs/debug)."""
        lines = []
        names = self.poly.dim_names
        pnames = self.poly.param_names

        def expr(r, k, flip):
            terms = []
            for j in range(k):
                c = -r[j] if not flip else r[j]
                if c:
                    terms.append(f"{'+' if c > 0 else ''}{c}*{names[j]}")
            for j in range(self.nparam):
                c = r[k + 1 + j]
                c = -c if not flip else c
                if c:
                    terms.append(f"{'+' if c > 0 else ''}{c}*{pnames[j]}")
            c = -r[-1] if not flip else r[-1]
            if c or not terms:
                terms.append(f"{'+' if c > 0 else ''}{c}")
            return " ".join(terms)

        for level in self.levels:
            k = level.k
            lbs = [f"ceild({expr(r, k, False)}, {r[k]})" for r in level.lowers]
            ubs = [f"floord({expr(r, k, True)}, {-r[k]})" for r in level.uppers]
            lb = lbs[0] if len(lbs) == 1 else "max(" + ", ".join(lbs) + ")"
            ub = ubs[0] if len(ubs) == 1 else "min(" + ", ".join(ubs) + ")"
            lines.append("  " * k + f"for ({names[k]} = {lb}; {names[k]} <= {ub}; {names[k]}++)")
        lines.append("  " * self.ndim + "body(" + ", ".join(names) + ");")
        return "\n".join(lines)
