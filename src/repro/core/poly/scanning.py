"""Lexicographic scanning of integer points — the paper's generated loops.

The paper's codegen (§4) turns dependence polyhedra into loop nests that scan
predecessors/successors of a task (get/put/autodec loops).  Here a
:class:`LoopNest` plays that role: it precomputes, per loop level, the
Fourier-Motzkin projection of the polyhedron onto the outer dims, so that at
"run time" (task execution) each level's bounds are cheap affine min/max
evaluations — exactly like generated C loop bounds.

Three evaluation backends share the same per-level systems:

* ``compiled`` (default) — the projected bounds are normalized once, at
  construction, into integer ``ceild``/``floord`` form (``-(rest // a)`` /
  ``rest // a`` with ``a > 0``, and a unit-coefficient fast path that drops
  the division entirely).  ``iterate``/``count`` then run *generated Python
  source* — an actual loop nest compiled per polyhedron, with parameter-only
  bounds hoisted out of the loops — so scanning behaves like the paper's
  generated C loops: pure integer arithmetic, no per-point allocation.
* ``numpy`` — batch enumeration: :meth:`LoopNest.iterate_array` /
  :meth:`LoopNest.count_vectorized` run *generated NumPy source* that emits
  whole wavefronts of points at once (``arange`` per level, ceil/floor
  division applied as array ops, ragged levels expanded with the
  repeat/cumsum trick) and returns a raveled ``(N, ndim)`` int64 array in
  the same lexicographic order the scalar loops produce.  The per-point
  scalar APIs (``iterate``/``count``) delegate to the compiled integer
  path.  Both reuse the same ``_IntRow`` normalization.
* ``fraction`` — the original per-call ``fractions.Fraction`` evaluation,
  retained as the reference oracle for the equivalence regression tests.

Compiled scan/count functions (scalar and NumPy) are cached in a module
table keyed by the **canonical polyhedron**, so identical dependence
polyhedra across graphs share one codegen (see :func:`scan_cache_info`).

Scanning is exact over the integers: level-k bounds come from the rational
projection, and integer-empty inner ranges simply produce empty loops.
Array enumeration uses int64; coefficients/params that overflow int64 are
out of scope (the scalar paths stay exact at arbitrary precision).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction
from typing import Iterator, Optional, Sequence

import numpy as np

from .polyhedron import Polyhedron
from .projection import project_out

F0 = Fraction(0)

BACKENDS = ("compiled", "numpy", "fraction")

# --------------------------------------------------------------------------
# Compiled-scan cache: canonical polyhedron -> compiled artifacts.  Two
# LoopNests over equal canonical polyhedra (e.g. the same dependence in two
# graphs) share one generated scan/count function per flavor.
_SCAN_CACHE: dict[tuple, dict] = {}
_SCAN_CACHE_STATS = {"hits": 0, "misses": 0}


def scan_cache_info() -> dict:
    """Hit/miss counters and size of the compiled-scan cache."""
    return {**_SCAN_CACHE_STATS, "size": len(_SCAN_CACHE)}


def clear_scan_cache() -> None:
    _SCAN_CACHE.clear()
    _SCAN_CACHE_STATS["hits"] = 0
    _SCAN_CACHE_STATS["misses"] = 0


def _cache_slot(key: tuple, flavor: str, build):
    """Fetch or build the compiled artifacts for one codegen flavor."""
    entry = _SCAN_CACHE.setdefault(key, {})
    got = entry.get(flavor)
    if got is not None:
        _SCAN_CACHE_STATS["hits"] += 1
        return got
    _SCAN_CACHE_STATS["misses"] += 1
    entry[flavor] = got = build()
    return got


def _row_ints(row) -> tuple[int, ...]:
    """Scale a rational constraint row to integers (positive factor: exact)."""
    den = 1
    for c in row:
        den = den * c.denominator // math.gcd(den, c.denominator)
    return tuple(int(c * den) for c in row)


# ------------------------------------------------------------------ sharding
SHARD_LO, SHARD_HI = "__slo", "__shi"


def shard_polyhedron(poly: Polyhedron) -> Polyhedron:
    """Expose the outermost dim's scan range as two extra parameters.

    Returns the same point set constrained by ``__slo <= d0 <= __shi`` with
    ``__slo``/``__shi`` appended to the parameter list.  A :class:`LoopNest`
    over the result scans exactly the rows of the full lexicographic scan
    whose outermost coordinate falls in ``[lo, hi]`` — in the same order —
    so concatenating block scans over a partition of the outer range is
    byte-identical to one full scan.

    Every shard of one polyhedron shares this single extended polyhedron
    (the block bounds travel as parameter *values*), so the canonical-key
    scan cache compiles each unit once per process no matter how many
    shards it is split into.
    """
    assert poly.ndim > 0, "cannot shard a 0-dim polyhedron"
    assert SHARD_LO not in poly.param_names, "polyhedron is already sharded"
    nd, np_ = poly.ndim, poly.nparam
    F1 = Fraction(1)

    def ext(row):
        return row[:nd + np_] + (F0, F0) + row[-1:]

    lo_row = [F0] * (nd + np_ + 3)
    lo_row[0], lo_row[nd + np_] = F1, -F1          # d0 - __slo >= 0
    hi_row = [F0] * (nd + np_ + 3)
    hi_row[0], hi_row[nd + np_ + 1] = -F1, F1      # __shi - d0 >= 0
    return Polyhedron(
        poly.dim_names, poly.param_names + (SHARD_LO, SHARD_HI),
        tuple(ext(r) for r in poly.ineqs) + (tuple(lo_row), tuple(hi_row)),
        tuple(ext(r) for r in poly.eqs)).canonical()


@dataclass
class _Level:
    """Bounds for one loop dim: rows over [outer dims..., this dim, params, 1].

    The level-k system has k+1 dims (outer dims + this one); parameters start
    at column k+1.
    """
    lowers: list[tuple]   # a_k > 0 rows: d_k >= ceil(-(rest)/a_k)
    uppers: list[tuple]   # a_k < 0 rows: d_k <= floor(rest/(-a_k))
    k: int

    @property
    def param_off(self) -> int:
        return self.k + 1


@dataclass
class _IntRow:
    """One bound row in integer ceil/floor-division form.

    ``rest = const + pre·prefix + par·params``; the bound contribution is
    ``-(rest // a)`` for lowers, ``rest // a`` for uppers, with ``a > 0``.
    ``pre`` is sparse ((outer-dim index, coeff) pairs) so rectangular rows
    cost nothing per outer iteration.
    """
    a: int                          # positive divisor (1 = fast path)
    pre: tuple[tuple[int, int], ...]  # nonzero outer-dim coefficients
    par: tuple[int, ...]            # dense parameter coefficients
    const: int


class LoopNest:
    """Scan the integer points of ``poly`` in lexicographic dim order."""

    def __init__(self, poly: Polyhedron, simplify: str = "auto",
                 backend: str = "compiled"):
        assert backend in BACKENDS, backend
        self.backend = backend
        self.poly = poly.canonical()
        self.ndim = poly.ndim
        self.nparam = poly.nparam
        self.levels: list[_Level] = []
        self._infeasible = False
        # guards: rows with no dim support (pure parameter constraints);
        # they surface in the outermost projected system and must be checked
        # at evaluation time or infeasible parameter values scan garbage.
        self._guards: list[tuple] = []
        cur = self.poly
        systems = [None] * self.ndim
        for k in range(self.ndim - 1, -1, -1):
            systems[k] = cur
            if k > 0:
                cur = project_out(cur, [k], simplify=simplify)
        if self.ndim == 0:
            self._guards = list(self.poly.all_rows_as_ineqs())
            self._compile_static()
            return
        for k in range(self.ndim):
            sys_k = systems[k]
            rows = sys_k.all_rows_as_ineqs()
            lowers, uppers = [], []
            for r in rows:
                c = r[k]
                if c > 0:
                    lowers.append(r)
                elif c < 0:
                    uppers.append(r)
                elif k == 0:
                    # pure-parameter guard (dim coeff 0 in the 1-dim system)
                    if all(x == 0 for x in r[:-1]):
                        if r[-1] < 0:
                            self._infeasible = True
                    else:
                        self._guards.append(r)
            self.levels.append(_Level(lowers, uppers, k))
        self._compile_static()

    # ----------------------------------------------------- compile (integer)
    def _compile_static(self) -> None:
        """Normalize guards and per-level bounds to integer form, once."""
        off = 1 if self.ndim else 0
        self._int_guards: list[tuple[tuple[int, ...], int]] = []
        for r in self._guards:
            ir = _row_ints(r)
            self._int_guards.append(
                (ir[off:off + self.nparam], ir[-1]))
        self._int_levels: list[tuple[list[_IntRow], list[_IntRow]]] = []
        for level in self.levels:
            k, poff = level.k, level.param_off
            los, ups = [], []
            for r in level.lowers:
                ir = _row_ints(r)
                los.append(_IntRow(
                    a=ir[k],
                    pre=tuple((j, ir[j]) for j in range(k) if ir[j]),
                    par=ir[poff:poff + self.nparam],
                    const=ir[-1]))
            for r in level.uppers:
                ir = _row_ints(r)
                ups.append(_IntRow(
                    a=-ir[k],
                    pre=tuple((j, ir[j]) for j in range(k) if ir[j]),
                    par=ir[poff:poff + self.nparam],
                    const=ir[-1]))
            self._int_levels.append((los, ups))
        self._scan_fn = None   # generated lazily (codegen is not free)
        self._count_fn = None
        self._gen_source: Optional[str] = None
        self._scan_np_fn = None
        self._count_np_fn = None
        self._np_source: Optional[str] = None
        self._block_nest: Optional["LoopNest"] = None
        # canonical-polyhedron cache key: rows are tuples of Fractions.
        self._cache_key = (self.poly.dim_names, self.poly.param_names,
                           self.poly.ineqs, self.poly.eqs)

    def feasible(self, params) -> bool:
        """Evaluate the pure-parameter guards (integer arithmetic)."""
        if self._infeasible:
            return False
        pv = self._param_vec(params)
        for par, const in self._int_guards:
            v = const
            for c, p in zip(par, pv):
                if c:
                    v += c * p
            if v < 0:
                return False
        return True

    # ------------------------------------------------------------------ eval
    def _bounds(self, level: _Level, prefix: Sequence[int],
                params: Sequence[int]) -> tuple[Optional[int], Optional[int]]:
        """Integer [lb, ub] for dim k given outer values; None = unbounded."""
        if self.backend == "compiled":
            return self._bounds_int(level.k, prefix, params)
        return self._bounds_fraction(level, prefix, params)

    def _bounds_int(self, k: int, prefix: Sequence[int],
                    params: Sequence[int]) -> tuple[Optional[int], Optional[int]]:
        """Compiled path: pure-integer ceil/floor division bound evaluation."""
        los, ups = self._int_levels[k]
        lb: Optional[int] = None
        ub: Optional[int] = None
        for r in los:
            rest = r.const
            for j, c in r.pre:
                rest += c * prefix[j]
            for c, p in zip(r.par, params):
                if c:
                    rest += c * p
            v = -rest if r.a == 1 else -(rest // r.a)
            if lb is None or v > lb:
                lb = v
        for r in ups:
            rest = r.const
            for j, c in r.pre:
                rest += c * prefix[j]
            for c, p in zip(r.par, params):
                if c:
                    rest += c * p
            v = rest if r.a == 1 else rest // r.a
            if ub is None or v < ub:
                ub = v
        return lb, ub

    def _bounds_fraction(self, level: _Level, prefix: Sequence[int],
                         params: Sequence[int]) -> tuple[Optional[int], Optional[int]]:
        """Reference path: the original per-call Fraction evaluation."""
        k = level.k
        off = level.param_off
        lb: Optional[int] = None
        ub: Optional[int] = None
        for r in level.lowers:
            a = r[k]
            rest = r[-1]
            for j in range(k):
                rest += r[j] * prefix[j]
            for j in range(self.nparam):
                rest += r[off + j] * params[j]
            v = math.ceil(Fraction(-rest, 1) / a)
            lb = v if lb is None else max(lb, v)
        for r in level.uppers:
            a = -r[k]
            rest = r[-1]
            for j in range(k):
                rest += r[j] * prefix[j]
            for j in range(self.nparam):
                rest += r[off + j] * params[j]
            v = math.floor(Fraction(rest, 1) / a)
            ub = v if ub is None else min(ub, v)
        return lb, ub

    # --------------------------------------------------------------- codegen
    def _rest_src(self, r: _IntRow) -> str:
        terms = []
        for j, c in enumerate(r.par):
            if c:
                terms.append(f"{c:+d}*p{j}")
        for j, c in r.pre:
            terms.append(f"{c:+d}*d{j}")
        if r.const or not terms:
            terms.append(f"{r.const:+d}")
        return " ".join(terms)

    def _bound_src(self, r: _IntRow, lower: bool) -> str:
        rest = self._rest_src(r)
        if lower:
            return f"-({rest})" if r.a == 1 else f"-(({rest}) // {r.a})"
        return f"({rest})" if r.a == 1 else f"({rest}) // {r.a}"

    def _emit(self) -> str:
        """Generate Python source for the scan and count loop nests.

        Mirrors the paper's generated C loops: ``ceild``/``floord`` become
        integer floor division, parameter-only bounds are hoisted to the
        function prologue, and the innermost count level is closed-form.
        """
        n = self.ndim
        head: list[str] = []
        for j in range(self.nparam):
            head.append(f"    p{j} = pv[{j}]")
        guards = []
        if self._infeasible:
            guards.append("    if True:")
        elif self._int_guards:
            conds = []
            for par, const in self._int_guards:
                r = _IntRow(1, (), par, const)
                conds.append(f"({self._rest_src(r)}) < 0")
            guards.append(f"    if {' or '.join(conds)}:")
        # per-level bound expressions, hoisting parameter-only rows
        hoist: list[str] = []
        lb_expr: list[Optional[str]] = []
        ub_expr: list[Optional[str]] = []
        for k in range(n):
            los, ups = self._int_levels[k]
            stat_l = [self._bound_src(r, True) for r in los if not r.pre]
            dyn_l = [self._bound_src(r, True) for r in los if r.pre]
            stat_u = [self._bound_src(r, False) for r in ups if not r.pre]
            dyn_u = [self._bound_src(r, False) for r in ups if r.pre]
            if stat_l:
                src = stat_l[0] if len(stat_l) == 1 else "max(%s)" % ", ".join(stat_l)
                hoist.append(f"    slb{k} = {src}")
                dyn_l = [f"slb{k}"] + dyn_l
            if stat_u:
                src = stat_u[0] if len(stat_u) == 1 else "min(%s)" % ", ".join(stat_u)
                hoist.append(f"    sub{k} = {src}")
                dyn_u = [f"sub{k}"] + dyn_u
            lb_expr.append(None if not dyn_l else
                           (dyn_l[0] if len(dyn_l) == 1 else "max(%s)" % ", ".join(dyn_l)))
            ub_expr.append(None if not dyn_u else
                           (dyn_u[0] if len(dyn_u) == 1 else "min(%s)" % ", ".join(dyn_u)))

        def body(kind: str) -> list[str]:
            out: list[str] = [f"def __{kind}(pv):"]
            out += head
            if guards:
                out.append(guards[0])
                out.append("        return" if kind == "scan" else "        return 0")
            if kind == "count":
                out.append("    total = 0")
            out += hoist
            ind = "    "
            last = n - 1
            for k in range(n):
                if lb_expr[k] is None or ub_expr[k] is None:
                    nm = self.poly.dim_names[k]
                    out.append(f"{ind}raise ValueError("
                               f"\"dim {k} ({nm}) is unbounded\")")
                    if kind == "scan":
                        # unreachable, but forces generator semantics so an
                        # empty outer range yields [] and a non-empty one
                        # raises on first next() — like the fraction path
                        out.append(f"{ind}yield ()")
                    else:
                        out.append("    return total")
                    return out
                if kind == "count" and k == last:
                    out.append(f"{ind}__lo = {lb_expr[k]}")
                    out.append(f"{ind}__hi = {ub_expr[k]}")
                    out.append(f"{ind}if __hi >= __lo:")
                    out.append(f"{ind}    total += __hi - __lo + 1")
                else:
                    out.append(f"{ind}for d{k} in range({lb_expr[k]}, "
                               f"{ub_expr[k]} + 1):")
                    ind += "    "
            if kind == "scan":
                tup = ", ".join(f"d{k}" for k in range(n)) + ("," if n == 1 else "")
                out.append(f"{ind}yield ({tup})")
            else:
                out.append("    return total")
            return out

        return "\n".join(body("scan") + [""] + body("count")) + "\n"

    def _compile_fns(self) -> None:
        def build():
            src = self._emit()
            ns: dict = {}
            exec(compile(src, f"<loopnest {self.poly.dim_names}>", "exec"), ns)
            return (src, ns["__scan"], ns["__count"])

        self._gen_source, self._scan_fn, self._count_fn = _cache_slot(
            self._cache_key, "scalar", build)

    def generated_source(self) -> str:
        """The generated Python loop nest (compiled backend; docs/debug)."""
        if self._scan_fn is None and self.ndim:
            self._compile_fns()
        return self._gen_source or ""

    # ------------------------------------------------------- codegen (numpy)
    def _emit_numpy(self) -> str:
        """Generate NumPy source for batch scan and count.

        The same ``_IntRow`` bounds drive array arithmetic: each level either
        has parameter-only (static) bounds — expanded with ``repeat``/``tile``
        like a meshgrid axis — or outer-dim-dependent (ragged) bounds, where
        per-prefix extents are clipped and expanded with the repeat/cumsum
        trick.  The scan returns a raveled ``(N, ndim)`` int64 array in exact
        lexicographic order; the count closes the innermost level in form.
        """
        n = self.ndim
        head = [f"    p{j} = pv[{j}]" for j in range(self.nparam)]
        guard_cond = None
        if self._infeasible:
            guard_cond = "True"
        elif self._int_guards:
            conds = []
            for par, const in self._int_guards:
                r = _IntRow(1, (), par, const)
                conds.append(f"({self._rest_src(r)}) < 0")
            guard_cond = " or ".join(conds)

        # per-level bound sources; static (parameter-only) rows hoisted
        hoist: list[str] = []
        lb_src: list[Optional[str]] = []
        ub_src: list[Optional[str]] = []
        lb_static: list[bool] = []
        dynamic: list[bool] = []

        def fold(fn: str, parts: list[str]) -> str:
            out = parts[0]
            for p in parts[1:]:
                out = f"_np.{fn}({out}, {p})"
            return out

        for k in range(n):
            los, ups = self._int_levels[k]
            stat_l = [self._bound_src(r, True) for r in los if not r.pre]
            dyn_l = [self._bound_src(r, True) for r in los if r.pre]
            stat_u = [self._bound_src(r, False) for r in ups if not r.pre]
            dyn_u = [self._bound_src(r, False) for r in ups if r.pre]
            if stat_l:
                src = stat_l[0] if len(stat_l) == 1 else "max(%s)" % ", ".join(stat_l)
                hoist.append(f"    slb{k} = {src}")
            if stat_u:
                src = stat_u[0] if len(stat_u) == 1 else "min(%s)" % ", ".join(stat_u)
                hoist.append(f"    sub{k} = {src}")
            if not (stat_l or dyn_l) or not (stat_u or dyn_u):
                lb_src.append(None)
                ub_src.append(None)
                lb_static.append(True)
                dynamic.append(False)
                continue
            l_parts = ([f"slb{k}"] if stat_l else []) + dyn_l
            u_parts = ([f"sub{k}"] if stat_u else []) + dyn_u
            lb_src.append(fold("maximum", l_parts))
            ub_src.append(fold("minimum", u_parts))
            lb_static.append(not dyn_l)
            dynamic.append(bool(dyn_l or dyn_u))

        def body(kind: str) -> list[str]:
            out = [f"def __{kind}_np(pv):"]
            out += head
            empty = f"_np.empty((0, {n}), dtype=_np.int64)"
            ret_nothing = f"return {empty}" if kind == "scan" else "return 0"
            if guard_cond:
                out.append(f"    if {guard_cond}:")
                out.append(f"        {ret_nothing}")
            out += hoist
            # which outer-dim columns each level must carry forward: the scan
            # needs every dim; the count only dims referenced by deeper bounds
            if kind == "scan":
                carry_after = [set(range(k + 1)) for k in range(n)]
            else:
                carry_after = []
                for k in range(n):
                    need: set[int] = set()
                    for k2 in range(k + 1, n):
                        los2, ups2 = self._int_levels[k2]
                        for r in los2 + ups2:
                            need |= {j for j, _ in r.pre}
                    carry_after.append({j for j in need if j <= k})
            out.append("    m = 1")
            last = n - 1
            for k in range(n):
                if lb_src[k] is None or ub_src[k] is None:
                    nm = self.poly.dim_names[k]
                    out.append(f"    raise ValueError("
                               f"\"dim {k} ({nm}) is unbounded\")")
                    return out
                carry = sorted(carry_after[k])
                if not dynamic[k]:
                    out.append(f"    lb{k} = {lb_src[k]}")
                    out.append(f"    ub{k} = {ub_src[k]}")
                    if kind == "count" and k == last:
                        out.append(f"    return m * (ub{k} - lb{k} + 1) "
                                   f"if ub{k} >= lb{k} else 0")
                        return out
                    out.append(f"    n{k} = ub{k} - lb{k} + 1")
                    out.append(f"    if n{k} <= 0:")
                    out.append(f"        {ret_nothing}")
                    for j in carry:
                        if j < k:
                            out.append(f"    d{j} = _np.repeat(d{j}, n{k})")
                    if k in carry:
                        out.append(f"    d{k} = _np.tile(_np.arange(lb{k}, "
                                   f"ub{k} + 1, dtype=_np.int64), m)")
                    out.append(f"    m = m * n{k}")
                else:
                    out.append(f"    lb{k} = {lb_src[k]}")
                    out.append(f"    ub{k} = {ub_src[k]}")
                    out.append(f"    cnt{k} = _np.maximum(ub{k} - lb{k} + 1, 0)")
                    if kind == "count" and k == last:
                        out.append(f"    return int(cnt{k}.sum())")
                        return out
                    out.append(f"    csum{k} = _np.cumsum(cnt{k})")
                    out.append(f"    t{k} = int(csum{k}[-1]) if m else 0")
                    out.append(f"    if t{k} == 0:")
                    out.append(f"        {ret_nothing}")
                    if carry:
                        out.append(f"    idx{k} = _np.repeat(_np.arange(m), cnt{k})")
                        for j in carry:
                            if j < k:
                                out.append(f"    d{j} = d{j}[idx{k}]")
                        if k in carry:
                            out.append(f"    off{k} = _np.arange(t{k}, "
                                       f"dtype=_np.int64) - "
                                       f"_np.repeat(csum{k} - cnt{k}, cnt{k})")
                            base = f"lb{k}" if lb_static[k] else f"lb{k}[idx{k}]"
                            out.append(f"    d{k} = {base} + off{k}")
                    out.append(f"    m = t{k}")
            if kind == "scan":
                cols = ", ".join(f"d{k}" for k in range(n))
                out.append(f"    return _np.stack(({cols},), axis=1)")
            else:
                out.append("    return m")
            return out

        return "\n".join(body("scan") + [""] + body("count")) + "\n"

    def _compile_np_fns(self) -> None:
        def build():
            src = self._emit_numpy()
            ns: dict = {"_np": np}
            exec(compile(src, f"<loopnest-np {self.poly.dim_names}>", "exec"), ns)
            return (src, ns["__scan_np"], ns["__count_np"])

        self._np_source, self._scan_np_fn, self._count_np_fn = _cache_slot(
            self._cache_key, "numpy", build)

    def generated_numpy_source(self) -> str:
        """The generated NumPy batch enumerator (docs/debug)."""
        if self._scan_np_fn is None and self.ndim:
            self._compile_np_fns()
        return self._np_source or ""

    # --------------------------------------------------------------- iterate
    def iterate(self, params: dict[str, int] | Sequence[int] = ()) -> Iterator[tuple[int, ...]]:
        """Yield every integer point (requires bounded dims).

        The ``numpy`` backend shares the compiled scalar path here; its batch
        API is :meth:`iterate_array`.
        """
        pv = self._param_vec(params)
        if self.ndim == 0:
            return iter((((),) if self.feasible(pv) else ()))
        if self.backend != "fraction":
            if self._scan_fn is None:
                self._compile_fns()
            return self._scan_fn(pv)
        return self._iterate_fraction(pv)

    def iterate_array(self, params: dict[str, int] | Sequence[int] = ()) -> "np.ndarray":
        """All integer points as a raveled ``(N, ndim)`` int64 array.

        Lexicographic row order, identical to :meth:`iterate`.  Whole levels
        are emitted as index arithmetic (generated NumPy source) — no
        per-point Python dispatch.  Available on every backend.
        """
        pv = self._param_vec(params)
        if self.ndim == 0:
            n = 1 if self.feasible(pv) else 0
            return np.zeros((n, 0), dtype=np.int64)
        if self._scan_np_fn is None:
            self._compile_np_fns()
        return self._scan_np_fn(pv)

    def count_vectorized(self, params: dict[str, int] | Sequence[int] = ()) -> int:
        """Point count via the generated NumPy enumerator (array bounds)."""
        pv = self._param_vec(params)
        if self.ndim == 0:
            return 1 if self.feasible(pv) else 0
        if self._count_np_fn is None:
            self._compile_np_fns()
        return int(self._count_np_fn(pv))

    def _iterate_fraction(self, pv) -> Iterator[tuple[int, ...]]:
        if not self.feasible(pv):
            return
        yield from self._rec(0, [], pv)

    def _rec(self, k: int, prefix: list[int], pv) -> Iterator[tuple[int, ...]]:
        if k == self.ndim:
            yield tuple(prefix)
            return
        lb, ub = self._bounds_fraction(self.levels[k], prefix, pv)
        if lb is None or ub is None:
            raise ValueError(f"dim {k} ({self.poly.dim_names[k]}) is unbounded")
        for v in range(lb, ub + 1):
            prefix.append(v)
            yield from self._rec(k + 1, prefix, pv)
            prefix.pop()

    def count(self, params: dict[str, int] | Sequence[int] = ()) -> int:
        """Number of integer points (innermost level counted closed-form)."""
        pv = self._param_vec(params)
        if self.ndim == 0:
            return 1 if self.feasible(pv) else 0
        if self.backend != "fraction":
            if self._count_fn is None:
                self._compile_fns()
            return self._count_fn(pv)
        if not self.feasible(pv):
            return 0
        return self._count_rec(0, [], pv)

    def _count_rec(self, k: int, prefix: list[int], pv) -> int:
        lb, ub = self._bounds_fraction(self.levels[k], prefix, pv)
        if lb is None or ub is None:
            raise ValueError(f"dim {k} is unbounded; cannot count")
        if ub < lb:
            return 0
        if k == self.ndim - 1:
            return ub - lb + 1
        total = 0
        for v in range(lb, ub + 1):
            prefix.append(v)
            total += self._count_rec(k + 1, prefix, pv)
            prefix.pop()
        return total

    def outer_bounds(self, params=()) -> Optional[tuple[int, int]]:
        """Static integer bounds ``[lb, ub]`` of the outermost dim.

        Level-0 bounds never reference outer dims, so they evaluate from the
        parameters alone — this is what the shard planner partitions.  Returns
        ``None`` when the nest is 0-dim, infeasible at these params, or the
        outer dim is unbounded (callers fall back to a single local scan).
        """
        pv = self._param_vec(params)
        if self.ndim == 0 or not self.feasible(pv):
            return None
        los, ups = self._int_levels[0]
        lb: Optional[int] = None
        ub: Optional[int] = None
        for r in los:
            rest = r.const + sum(c * p for c, p in zip(r.par, pv) if c)
            v = -rest if r.a == 1 else -(rest // r.a)
            if lb is None or v > lb:
                lb = v
        for r in ups:
            rest = r.const + sum(c * p for c, p in zip(r.par, pv) if c)
            v = rest if r.a == 1 else rest // r.a
            if ub is None or v < ub:
                ub = v
        if lb is None or ub is None:
            return None
        return lb, ub

    def outer_only_params(self) -> frozenset[int]:
        """Parameter indices that bound ONLY the outermost loop dim.

        A parameter is *outer-only* when its coefficient is zero in every
        level-k bound row for k >= 1: fixing the outer coordinate, the inner
        scan is independent of it.  Pure-parameter guards do not disqualify
        (they gate feasibility of the whole scan, never row content), so for
        two feasible parameter vectors differing only in outer-only params,
        the rows whose outer coordinate lies in both scans' ranges are
        byte-identical — the reuse invariant behind the graph cache's
        incremental re-materialization (:mod:`repro.core.edt.cache`).
        """
        inner = set()
        for k in range(1, self.ndim):
            los, ups = self._int_levels[k]
            for r in los + ups:
                for j, c in enumerate(r.par):
                    if c:
                        inner.add(j)
        return frozenset(j for j in range(self.nparam) if j not in inner)

    def block_nest(self) -> "LoopNest":
        """The ``__slo``/``__shi``-extended twin of this nest (lazy, cached).

        Scans exactly the rows of the full scan whose outermost coordinate
        falls in ``[lo, hi]`` when called with ``params + (lo, hi)`` — the
        same restricted polyhedron the shard planner partitions
        (:func:`shard_polyhedron`), shared here so driver-side consumers
        (the graph cache's incremental path) reuse one canonical compile.
        """
        assert self.ndim > 0, "cannot block-restrict a 0-dim nest"
        if self._block_nest is None:
            self._block_nest = LoopNest(shard_polyhedron(self.poly),
                                        backend=self.backend)
        return self._block_nest

    def first(self, params=()) -> Optional[tuple[int, ...]]:
        return next(self.iterate(params), None)

    def is_empty_at(self, params=()) -> bool:
        return self.first(params) is None

    # ------------------------------------------------------------- structure
    def is_rectangular(self) -> bool:
        """True if every level's bounds are independent of outer dims.

        This is the shape heuristic of §4.3: rectangular nests admit an O(n)
        closed-form enumerator; ragged ones are counted by scanning.
        """
        for level in self.levels:
            for r in level.lowers + level.uppers:
                if any(r[j] != 0 for j in range(level.k)):
                    return False
        return True

    def _param_vec(self, params) -> list[int]:
        if isinstance(params, dict):
            return [params[n] for n in self.poly.param_names]
        pv = list(params)
        assert len(pv) == self.nparam, (
            f"expected {self.nparam} params {self.poly.param_names}, got {pv}")
        return pv

    # ---------------------------------------------------------------- codegen
    def pretty_loops(self) -> str:
        """Human-readable pseudo-C of the generated loop nest (docs/debug)."""
        lines = []
        names = self.poly.dim_names
        pnames = self.poly.param_names

        def expr(r, k, flip):
            terms = []
            for j in range(k):
                c = -r[j] if not flip else r[j]
                if c:
                    terms.append(f"{'+' if c > 0 else ''}{c}*{names[j]}")
            for j in range(self.nparam):
                c = r[k + 1 + j]
                c = -c if not flip else c
                if c:
                    terms.append(f"{'+' if c > 0 else ''}{c}*{pnames[j]}")
            c = -r[-1] if not flip else r[-1]
            if c or not terms:
                terms.append(f"{'+' if c > 0 else ''}{c}")
            return " ".join(terms)

        for level in self.levels:
            k = level.k
            lbs = [f"ceild({expr(r, k, False)}, {r[k]})" for r in level.lowers]
            ubs = [f"floord({expr(r, k, True)}, {-r[k]})" for r in level.uppers]
            lb = lbs[0] if len(lbs) == 1 else "max(" + ", ".join(lbs) + ")"
            ub = ubs[0] if len(ubs) == 1 else "min(" + ", ".join(ubs) + ")"
            lines.append("  " * k + f"for ({names[k]} = {lb}; {names[k]} <= {ub}; {names[k]}++)")
        lines.append("  " * self.ndim + "body(" + ", ".join(names) + ");")
        return "\n".join(lines)
