"""Parametric rational polyhedra in constraint form.

A :class:`Polyhedron` is ``{ x in Q^ndim : A.x + D.p + c >= 0,  E.x + F.p + g = 0 }``
where ``p`` is a vector of symbolic parameters (e.g. problem sizes ``N``).
Rows are stored over the combined column space ``[dims..., params..., 1]`` with
exact ``Fraction`` coefficients.

This is the substrate for the paper's §3: dependence polyhedra, tiling by
compression, direct sums, inflation, and the Fourier-Motzkin *projection*
baseline it is benchmarked against.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from fractions import Fraction
from typing import Iterable, Optional, Sequence

from .linalg import (Mat, Row, frac, is_zero_row, mat_inv, mat_vec,
                     row_normalize, vec, vec_mat)
from .lp import lp_feasible, lp_max, lp_min

F0 = Fraction(0)
F1 = Fraction(1)


def _dedupe(rows: Iterable[Row]) -> tuple[Row, ...]:
    seen, out = set(), []
    for r in rows:
        if r not in seen:
            seen.add(r)
            out.append(r)
    return tuple(out)


@dataclass(frozen=True)
class Polyhedron:
    dim_names: tuple[str, ...]
    param_names: tuple[str, ...]
    ineqs: tuple[Row, ...] = ()   # a.x + d.p + c >= 0
    eqs: tuple[Row, ...] = ()     # e.x + f.p + g  = 0

    # ---------------------------------------------------------------- basics
    @property
    def ndim(self) -> int:
        return len(self.dim_names)

    @property
    def nparam(self) -> int:
        return len(self.param_names)

    @property
    def ncol(self) -> int:
        return self.ndim + self.nparam + 1

    def __post_init__(self):
        for r in itertools.chain(self.ineqs, self.eqs):
            assert len(r) == self.ncol, (len(r), self.ncol)

    # -------------------------------------------------------------- builders
    @staticmethod
    def universe(dim_names: Sequence[str], param_names: Sequence[str] = ()) -> "Polyhedron":
        return Polyhedron(tuple(dim_names), tuple(param_names))

    @staticmethod
    def from_ineqs(dim_names, param_names, rows, eqs=()) -> "Polyhedron":
        rows = tuple(vec(r) for r in rows)
        eqs = tuple(vec(r) for r in eqs)
        return Polyhedron(tuple(dim_names), tuple(param_names), rows, eqs).canonical()

    @staticmethod
    def box(dim_names, lo: Sequence, hi: Sequence, param_names=()) -> "Polyhedron":
        """Axis-aligned box lo_i <= x_i <= hi_i (bounds are rationals)."""
        n, npar = len(dim_names), len(param_names)
        rows = []
        for i, (lb, ub) in enumerate(zip(lo, hi)):
            lo_row = [F0] * (n + npar + 1)
            lo_row[i] = F1
            lo_row[-1] = -frac(lb)
            hi_row = [F0] * (n + npar + 1)
            hi_row[i] = -F1
            hi_row[-1] = frac(ub)
            rows += [tuple(lo_row), tuple(hi_row)]
        return Polyhedron(tuple(dim_names), tuple(param_names), tuple(rows))

    # ---------------------------------------------------------- canonical form
    def canonical(self) -> "Polyhedron":
        """Normalize rows to coprime ints, drop tautologies, dedupe."""
        ineqs, eqs = [], []
        for r in self.eqs:
            r = row_normalize(r)
            if is_zero_row(r):
                continue
            if all(c == 0 for c in r[:-1]):
                # 0 = g with g != 0: infeasible; encode as 0 >= 1
                bad = list((F0,) * (self.ncol - 1)) + [Fraction(-1)]
                return Polyhedron(self.dim_names, self.param_names,
                                  (tuple(bad),), ())
            # canonical sign: first nonzero coefficient positive
            lead = next(c for c in r if c != 0)
            if lead < 0:
                r = tuple(-c for c in r)
            eqs.append(r)
        for r in self.ineqs:
            r = row_normalize(r)
            if all(c == 0 for c in r[:-1]):
                if r[-1] < 0:
                    bad = list((F0,) * (self.ncol - 1)) + [Fraction(-1)]
                    return Polyhedron(self.dim_names, self.param_names,
                                      (tuple(bad),), ())
                continue  # 0 >= -c, trivially true
            ineqs.append(r)
        return Polyhedron(self.dim_names, self.param_names,
                          _dedupe(ineqs), _dedupe(eqs))

    def all_rows_as_ineqs(self) -> tuple[Row, ...]:
        """Equalities expanded into constraint pairs (for LP / FM)."""
        rows = list(self.ineqs)
        for e in self.eqs:
            rows.append(e)
            rows.append(tuple(-c for c in e))
        return tuple(rows)

    # ------------------------------------------------------------- set algebra
    def intersect(self, other: "Polyhedron") -> "Polyhedron":
        assert self.dim_names == other.dim_names
        assert self.param_names == other.param_names
        return Polyhedron(self.dim_names, self.param_names,
                          _dedupe(self.ineqs + other.ineqs),
                          _dedupe(self.eqs + other.eqs)).canonical()

    def add_ineq(self, row: Sequence) -> "Polyhedron":
        return Polyhedron(self.dim_names, self.param_names,
                          self.ineqs + (vec(row),), self.eqs).canonical()

    def add_eq(self, row: Sequence) -> "Polyhedron":
        return Polyhedron(self.dim_names, self.param_names,
                          self.ineqs, self.eqs + (vec(row),)).canonical()

    # --------------------------------------------------------------- queries
    def is_empty(self) -> bool:
        """Empty for *all* parameter values (params treated as free rationals)."""
        nv = self.ndim + self.nparam
        return not lp_feasible(self.all_rows_as_ineqs(), nv)

    def is_empty_at(self, params: dict[str, int]) -> bool:
        return self.fix_params(params).is_empty()

    def sample(self) -> Optional[tuple[Fraction, ...]]:
        nv = self.ndim + self.nparam
        res = lp_min(self.all_rows_as_ineqs(), nv, [F0] * nv)
        return None if res.status == "infeasible" else res.x

    def contains_point(self, x: Sequence, params: Sequence = ()) -> bool:
        col = vec(list(x) + list(params) + [1])
        return (all(sum(a * b for a, b in zip(r, col)) >= 0 for r in self.ineqs)
                and all(sum(a * b for a, b in zip(r, col)) == 0 for r in self.eqs))

    def contains(self, other: "Polyhedron") -> bool:
        """self >= other as sets (for every parameter value)? Exact via LP."""
        assert self.ncol == other.ncol
        if other.is_empty():
            return True
        nv = self.ndim + self.nparam
        rows = other.all_rows_as_ineqs()
        for c in self.all_rows_as_ineqs():
            # min over `other` of c.x must be >= 0
            res = lp_min(rows, nv, c[:nv])
            if res.status == "unbounded":
                return False
            if res.status == "optimal" and res.value + c[nv] < 0:
                return False
        return True

    def equals(self, other: "Polyhedron") -> bool:
        return self.contains(other) and other.contains(self)

    def dim_bounds(self, i: int) -> tuple[Optional[Fraction], Optional[Fraction]]:
        """(min, max) of dimension i over the polyhedron (params free). None=unbounded."""
        nv = self.ndim + self.nparam
        obj = [F0] * nv
        obj[i] = F1
        rows = self.all_rows_as_ineqs()
        lo = lp_min(rows, nv, obj)
        hi = lp_max(rows, nv, obj)
        if lo.status == "infeasible":
            return (None, None)
        return (lo.value if lo.status == "optimal" else None,
                hi.value if hi.status == "optimal" else None)

    # ---------------------------------------------------------- substitutions
    def fix_params(self, params: dict[str, int]) -> "Polyhedron":
        """Substitute concrete values for a subset of parameters."""
        keep = [i for i, n in enumerate(self.param_names) if n not in params]
        newp = tuple(self.param_names[i] for i in keep)

        def conv(row: Row) -> Row:
            out = list(row[:self.ndim])
            const = row[-1]
            for i, name in enumerate(self.param_names):
                c = row[self.ndim + i]
                if name in params:
                    const += c * frac(params[name])
                else:
                    out.append(c)
            out.append(const)
            return tuple(out)

        return Polyhedron(self.dim_names, newp,
                          tuple(conv(r) for r in self.ineqs),
                          tuple(conv(r) for r in self.eqs)).canonical()

    def fix_dims(self, values: dict[int, Fraction]) -> "Polyhedron":
        """Substitute concrete values for a subset of dimensions (by index)."""
        keep = [i for i in range(self.ndim) if i not in values]
        newd = tuple(self.dim_names[i] for i in keep)

        def conv(row: Row) -> Row:
            out = []
            const = row[-1]
            for i in range(self.ndim):
                if i in values:
                    const += row[i] * frac(values[i])
                else:
                    out.append(row[i])
            out.extend(row[self.ndim:self.ndim + self.nparam])
            out.append(const)
            return tuple(out)

        return Polyhedron(newd, self.param_names,
                          tuple(conv(r) for r in self.ineqs),
                          tuple(conv(r) for r in self.eqs)).canonical()

    def preimage_affine(self, M: Mat, t: Row, new_dim_names: Sequence[str]) -> "Polyhedron":
        """{ y : M.y + t in self }  (x = M y + t substituted into constraints).

        M is ndim x len(new_dim_names); t length ndim. Parameters are untouched.
        """

        def conv(row: Row) -> Row:
            a = row[:self.ndim]
            rest = row[self.ndim:]
            ay = vec_mat(a, M)  # coefficients over y
            const_shift = sum((ai * ti for ai, ti in zip(a, t)), F0)
            out = list(ay) + list(rest[:-1]) + [rest[-1] + const_shift]
            return tuple(out)

        return Polyhedron(tuple(new_dim_names), self.param_names,
                          tuple(conv(r) for r in self.ineqs),
                          tuple(conv(r) for r in self.eqs)).canonical()

    def image_invertible(self, M: Mat, t: Row, new_dim_names: Sequence[str]) -> "Polyhedron":
        """{ M.x + t : x in self } for invertible M — exact, no projection.

        This is the paper's compression step: ``image(D, G^{-1})`` with
        M = G^{-1}.  Computed by substituting x = M^{-1}(y - t).
        """
        Minv = mat_inv(M)
        t_new = tuple(-c for c in mat_vec(Minv, t))
        return self.preimage_affine(Minv, t_new, new_dim_names)

    def rename(self, dim_names=None, param_names=None) -> "Polyhedron":
        return Polyhedron(tuple(dim_names) if dim_names else self.dim_names,
                          tuple(param_names) if param_names else self.param_names,
                          self.ineqs, self.eqs)

    def add_dims(self, names: Sequence[str], front: bool = False) -> "Polyhedron":
        """Embed into a larger space (new dims unconstrained)."""
        k = len(names)

        def conv(row: Row) -> Row:
            if front:
                return (F0,) * k + row
            return row[:self.ndim] + (F0,) * k + row[self.ndim:]

        dn = (tuple(names) + self.dim_names) if front else (self.dim_names + tuple(names))
        return Polyhedron(dn, self.param_names,
                          tuple(conv(r) for r in self.ineqs),
                          tuple(conv(r) for r in self.eqs))

    # ----------------------------------------------- §3.1 inflation (paper)
    def inflate_box(self, lo: Sequence, hi: Sequence) -> "Polyhedron":
        """Over-approximate ``self ⊕ Box(lo, hi)`` by shifting constraints.

        Paper §3.1: for each constraint a.x + b >= 0 the required offset is
        c_max(a) = max_{u in Box} (-a.u) = sum_i max(-a_i*lo_i, -a_i*hi_i).
        Same combinatorial structure (no new vertices/constraints).
        Equalities whose dim-part is nonzero become inequality pairs, inflated
        independently (an equality thickens into a slab under Minkowski sum).
        """
        lo = vec(lo)
        hi = vec(hi)
        assert len(lo) == self.ndim and len(hi) == self.ndim

        def shifted(row: Row) -> Row:
            c = sum((max(-row[i] * lo[i], -row[i] * hi[i]) for i in range(self.ndim)), F0)
            return row[:-1] + (row[-1] + c,)

        new_ineqs = [shifted(r) for r in self.ineqs]
        new_eqs = []
        for e in self.eqs:
            if all(e[i] == 0 for i in range(self.ndim)):
                new_eqs.append(e)  # pure-parameter equality: unaffected
            else:
                new_ineqs.append(shifted(e))
                new_ineqs.append(shifted(tuple(-c for c in e)))
        return Polyhedron(self.dim_names, self.param_names,
                          _dedupe(new_ineqs), tuple(new_eqs)).canonical()

    # ------------------------------------------------------------ repr/debug
    def pretty(self) -> str:
        names = list(self.dim_names) + list(self.param_names)

        def fmt(row: Row, op: str) -> str:
            terms = []
            for c, n in zip(row[:-1], names):
                if c == 0:
                    continue
                if c == 1:
                    terms.append(f"+{n}")
                elif c == -1:
                    terms.append(f"-{n}")
                else:
                    terms.append(f"{'+' if c > 0 else ''}{c}*{n}")
            if row[-1] != 0 or not terms:
                terms.append(f"{'+' if row[-1] > 0 else ''}{row[-1]}")
            return " ".join(terms) + f" {op} 0"

        lines = [fmt(r, ">=") for r in self.ineqs] + [fmt(r, "=") for r in self.eqs]
        return "{ [%s] : %s }" % (", ".join(self.dim_names), " and ".join(lines) or "true")

    def __repr__(self) -> str:  # pragma: no cover
        return f"Polyhedron({self.pretty()}, params={self.param_names})"
