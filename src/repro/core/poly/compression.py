"""The paper's §3: scalable inter-tile dependence computation by compression.

Given a pre-tiling dependence polyhedron ``Δ(I_s, I_t)`` and diagonal tiling
matrices ``G_s, G_t``:

    T = G^{-1} I - G^{-1} X,     0 <= X <= diag(G) - 1            (eqs 1-3)
    U = { -G^{-1} X }            (a hyper-rectangle, eq 4)
    Δ_T = image(Δ, G_{s,t}^{-1}) ⊕ U_{s,t}                        (eq 8)

The image under the invertible compression is a plain constraint rewrite
(no projection!), and the direct sum with the box ``U`` is either computed
exactly (validation oracle) or via the §3.1 *inflation* over-approximation,
which shifts each constraint outward by ``c_max(a)`` and adds no vertices.

``tile_dependence_projection`` implements the prior-art baseline the paper
benchmarks against: lift to ``(T_s, X_s, T_t, X_t)`` and Fourier-Motzkin the
``X`` dims away.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Sequence

from .linalg import Mat, Row, diag, frac, vec
from .polyhedron import Polyhedron
from .projection import minkowski_sum_box_exact, project_out

F0 = Fraction(0)
F1 = Fraction(1)


@dataclass(frozen=True)
class Tiling:
    """Orthogonal tiling: diagonal G with positive integer tile sizes."""
    sizes: tuple[int, ...]

    def __post_init__(self):
        assert all(isinstance(s, int) and s >= 1 for s in self.sizes), self.sizes

    @property
    def ndim(self) -> int:
        return len(self.sizes)

    def G(self) -> Mat:
        return diag([frac(s) for s in self.sizes])

    def u_box(self) -> tuple[Row, Row]:
        """The hyper-rectangle U = [-(g-1)/g, 0]^n of eq (4)."""
        lo = vec([Fraction(-(g - 1), g) for g in self.sizes])
        hi = vec([F0] * self.ndim)
        return lo, hi


def compress(domain: Polyhedron, tiling: Tiling,
             tile_dim_names: Sequence[str] | None = None) -> Polyhedron:
    """``image(D, G^{-1})`` — substitute I = G·T. Exact; no projection."""
    assert tiling.ndim == domain.ndim
    names = tuple(tile_dim_names or (f"{n}_T" for n in domain.dim_names))
    G = tiling.G()
    t0 = vec([0] * domain.ndim)
    return domain.preimage_affine(G, t0, names)


def tile_domain(domain: Polyhedron, tiling: Tiling, method: str = "inflate",
                tile_dim_names: Sequence[str] | None = None) -> Polyhedron:
    """Set of tile indices T whose tile contains a point of ``domain`` (eq 6).

    method: 'inflate' (production, §3.1 over-approximation — exact for the
    tilings used in practice because tile-domain constraints are integer
    translates) or 'exact' (direct-sum oracle via lifted projection).
    """
    P = compress(domain, tiling, tile_dim_names)
    lo, hi = tiling.u_box()
    if method == "inflate":
        return P.inflate_box(lo, hi)
    if method == "exact":
        return minkowski_sum_box_exact(P, lo, hi)
    raise ValueError(method)


def _combined(delta: Polyhedron, src_ndim: int, gs: Tiling, gt: Tiling) -> Tiling:
    assert delta.ndim == src_ndim + gt.ndim, (
        f"dependence has {delta.ndim} dims != {src_ndim}+{gt.ndim}")
    assert gs.ndim == src_ndim
    return Tiling(gs.sizes + gt.sizes)


def tile_dependence(delta: Polyhedron, src_ndim: int, gs: Tiling, gt: Tiling,
                    method: str = "inflate",
                    tile_dim_names: Sequence[str] | None = None) -> Polyhedron:
    """Paper eq (8): ``Δ_T = image(Δ, G_{s,t}^{-1}) ⊕ U_{s,t}``.

    ``delta`` lives in the Cartesian product of source and target iteration
    spaces (first ``src_ndim`` dims are the source's).
    """
    gst = _combined(delta, src_ndim, gs, gt)
    return tile_domain(delta, gst, method=method, tile_dim_names=tile_dim_names)


def tile_dependence_projection(delta: Polyhedron, src_ndim: int,
                               gs: Tiling, gt: Tiling,
                               simplify: str = "auto",
                               tile_dim_names: Sequence[str] | None = None
                               ) -> Polyhedron:
    """Prior-art baseline [2, 9, 14]: lift to (T, X) and project out X.

    Builds the 2(n_s+n_t)-dimensional system
        Δ(G_s T_s + X_s, G_t T_t + X_t),  0 <= X <= diag(G) - 1
    and eliminates all X dims with Fourier-Motzkin.  Worst-case cost is
    doubly exponential in the eliminated dims — the tractability problem
    §3 removes.
    """
    gst = _combined(delta, src_ndim, gs, gt)
    n = delta.ndim
    tnames = tuple(tile_dim_names or (f"{d}_T" for d in delta.dim_names))
    xnames = tuple(f"{d}_X" for d in delta.dim_names)

    # Map (T..., X...) -> I = G T + X : matrix [G | I_n], zero offset.
    G = gst.G()
    M = tuple(tuple(G[i][j] for j in range(n)) +
              tuple(F1 if i == j else F0 for j in range(n))
              for i in range(n))
    t0 = vec([0] * n)
    lifted = delta.preimage_affine(M, t0, tnames + xnames)

    xbox = Polyhedron.box(xnames,
                          [0] * n, [g - 1 for g in gst.sizes],
                          delta.param_names).add_dims(tnames, front=True)
    sys = lifted.intersect(xbox)
    return project_out(sys, list(range(n, 2 * n)), simplify=simplify)
