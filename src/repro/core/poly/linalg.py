"""Exact rational linear algebra over ``fractions.Fraction``.

Everything in ``repro.core.poly`` is exact: no floating point ever enters the
polyhedral computations (paper §3 relies on exact integer/rational sets).

Matrices are tuples-of-tuples of Fractions (immutable, hashable); small helper
functions implement the handful of operations the polyhedral layer needs:
matmul, inverse (Gauss-Jordan), identity, diagonal, row reduction.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Iterable, Sequence

Frac = Fraction
Row = tuple[Fraction, ...]
Mat = tuple[Row, ...]


def frac(x) -> Fraction:
    """Coerce ints / strings / Fractions to Fraction (floats are rejected)."""
    if isinstance(x, float):
        raise TypeError("floats are not allowed in exact polyhedral math: %r" % (x,))
    return Fraction(x)


def vec(xs: Iterable) -> Row:
    return tuple(frac(x) for x in xs)


def mat(rows: Iterable[Iterable]) -> Mat:
    return tuple(vec(r) for r in rows)


def zeros(n: int) -> Row:
    return (Fraction(0),) * n


def eye(n: int) -> Mat:
    return tuple(
        tuple(Fraction(1) if i == j else Fraction(0) for j in range(n))
        for i in range(n)
    )


def diag(ds: Sequence) -> Mat:
    ds = vec(ds)
    n = len(ds)
    return tuple(
        tuple(ds[i] if i == j else Fraction(0) for j in range(n)) for i in range(n)
    )


def mat_shape(m: Mat) -> tuple[int, int]:
    return (len(m), len(m[0]) if m else 0)


def mat_mul(a: Mat, b: Mat) -> Mat:
    n, k = mat_shape(a)
    k2, p = mat_shape(b)
    assert k == k2, f"shape mismatch {mat_shape(a)} @ {mat_shape(b)}"
    bt = tuple(zip(*b))
    return tuple(
        tuple(sum(x * y for x, y in zip(row, col)) for col in bt) for row in a
    )


def mat_vec(a: Mat, x: Row) -> Row:
    return tuple(sum(c * v for c, v in zip(row, x)) for row in a)


def vec_mat(x: Row, a: Mat) -> Row:
    """Row-vector times matrix: (x^T A)."""
    n, p = mat_shape(a)
    assert len(x) == n
    return tuple(sum(x[i] * a[i][j] for i in range(n)) for j in range(p))


def dot(x: Row, y: Row) -> Fraction:
    return sum((a * b for a, b in zip(x, y)), Fraction(0))


def mat_inv(m: Mat) -> Mat:
    """Exact inverse via Gauss-Jordan with partial (nonzero) pivoting."""
    n, k = mat_shape(m)
    assert n == k, "inverse needs a square matrix"
    aug = [list(row) + list(eye_row) for row, eye_row in zip(m, eye(n))]
    for col in range(n):
        piv = next((r for r in range(col, n) if aug[r][col] != 0), None)
        if piv is None:
            raise ZeroDivisionError("matrix is singular")
        aug[col], aug[piv] = aug[piv], aug[col]
        pv = aug[col][col]
        aug[col] = [x / pv for x in aug[col]]
        for r in range(n):
            if r != col and aug[r][col] != 0:
                f = aug[r][col]
                aug[r] = [x - f * y for x, y in zip(aug[r], aug[col])]
    return tuple(tuple(row[n:]) for row in aug)


def row_normalize(row: Row) -> Row:
    """Scale a constraint row to coprime integers (canonical form).

    Keeps the sign of the row; rows that are all-zero are returned unchanged.
    """
    from math import gcd

    den = 1
    for c in row:
        den = den * c.denominator // gcd(den, c.denominator)
    ints = [int(c * den) for c in row]
    g = 0
    for v in ints:
        g = gcd(g, abs(v))
    if g > 1:
        ints = [v // g for v in ints]
    return tuple(Fraction(v) for v in ints)


def is_zero_row(row: Row) -> bool:
    return all(c == 0 for c in row)


def rref(rows: list[list[Fraction]]) -> list[list[Fraction]]:
    """Reduced row echelon form (in place on a copy); drops zero rows."""
    rows = [list(r) for r in rows]
    m = len(rows)
    n = len(rows[0]) if m else 0
    lead = 0
    out = []
    for col in range(n):
        piv = next((r for r in range(lead, m) if rows[r][col] != 0), None)
        if piv is None:
            continue
        rows[lead], rows[piv] = rows[piv], rows[lead]
        pv = rows[lead][col]
        rows[lead] = [x / pv for x in rows[lead]]
        for r in range(m):
            if r != lead and rows[r][col] != 0:
                f = rows[r][col]
                rows[r] = [x - f * y for x, y in zip(rows[r], rows[lead])]
        lead += 1
        if lead == m:
            break
    for r in rows:
        if any(c != 0 for c in r):
            out.append(r)
    return out
