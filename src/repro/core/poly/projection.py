"""Fourier-Motzkin projection — the *baseline* tile-dependence method.

The prior-art technique ([2, 9, 14] in the paper) computes inter-tile
dependences by building the high-dimensional polyhedron over
``(T_s, X_s, T_t, X_t)`` and projecting out the intra-tile dims ``X``.
FM elimination scales poorly with dimension count (worst case doubly
exponential in eliminated dims) — which is precisely the tractability problem
the paper's compression method (``compression.py``) removes.

We implement FM exactly (rational arithmetic), with:
  * Gaussian elimination through equalities first (free eliminations),
  * canonical row normalization + syntactic dominance filtering,
  * optional exact LP-based redundancy pruning (``simplify='lp'``) to keep
    intermediate systems from exploding in the correctness tests.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Iterable, Sequence

from .linalg import is_zero_row, row_normalize
from .lp import lp_min
from .polyhedron import Polyhedron

F0 = Fraction(0)


def _dominance_filter(rows: Iterable[tuple]) -> list[tuple]:
    """Keep only the tightest constant per distinct coefficient vector."""
    best: dict[tuple, Fraction] = {}
    for r in rows:
        key, const = r[:-1], r[-1]
        if key not in best or const < best[key]:
            best[key] = const
    return [k + (c,) for k, c in best.items()]


def _lp_prune(rows: list[tuple], nv: int) -> list[tuple]:
    """Remove constraints implied by the others (exact, O(rows) LPs)."""
    rows = list(rows)
    i = 0
    while i < len(rows):
        others = rows[:i] + rows[i + 1:]
        if not others:
            break
        r = rows[i]
        res = lp_min(others, nv, r[:nv])
        if res.status == "optimal" and res.value + r[nv] >= 0:
            rows.pop(i)  # implied
        elif res.status == "infeasible":
            return [rows[i]] if False else rows  # empty set: keep as-is
        else:
            i += 1
    return rows


def eliminate_dim(ineqs: list[tuple], col: int) -> list[tuple]:
    """One FM elimination step on inequality rows (col = column index)."""
    pos, neg, zero = [], [], []
    for r in ineqs:
        c = r[col]
        if c > 0:
            pos.append(r)
        elif c < 0:
            neg.append(r)
        else:
            zero.append(r)
    out = list(zero)
    for p in pos:
        for n in neg:
            # p[col] > 0, n[col] < 0: combine to cancel col
            a, b = p[col], -n[col]
            row = tuple(b * pc + a * nc for pc, nc in zip(p, n))
            row = row_normalize(row)
            if is_zero_row(row):
                continue
            if all(c == 0 for c in row[:-1]):
                if row[-1] < 0:
                    return [row]  # infeasible marker: 0 >= positive
                continue
            out.append(row)
    return _dominance_filter(out)


def project_out(poly: Polyhedron, dims: Sequence[int],
                simplify: str = "auto", lp_threshold: int = 64) -> Polyhedron:
    """Project away the given dim indices (existential quantification).

    simplify: 'none' | 'auto' (LP-prune when the system grows past
    ``lp_threshold`` rows) | 'lp' (always LP-prune after each elimination).
    """
    dims = sorted(set(dims))
    keep = [i for i in range(poly.ndim) if i not in dims]

    eqs = [tuple(r) for r in poly.eqs]
    ineqs = [tuple(r) for r in poly.ineqs]

    # Gaussian elimination: use equalities to remove dims for free.
    remaining = list(dims)
    for d in list(remaining):
        pivot = next((e for e in eqs if e[d] != 0), None)
        if pivot is None:
            continue
        eqs.remove(pivot)

        def subst(row):
            if row[d] == 0:
                return row
            f = row[d] / pivot[d]
            return tuple(rc - f * pc for rc, pc in zip(row, pivot))

        eqs = [row_normalize(subst(e)) for e in eqs]
        eqs = [e for e in eqs if not is_zero_row(e)]
        ineqs = [row_normalize(subst(r)) for r in ineqs]
        ineqs = [r for r in ineqs if not is_zero_row(r)]
        remaining.remove(d)

    # FM on what's left. Equalities with support on eliminated dims must be
    # expanded (none remain after Gaussian elim unless duplicated; be safe).
    for d in remaining:
        extra = [e for e in eqs if e[d] != 0]
        if extra:
            for e in extra:
                eqs.remove(e)
                ineqs.append(e)
                ineqs.append(tuple(-c for c in e))
        ineqs = eliminate_dim(ineqs, d)
        if simplify == "lp" or (simplify == "auto" and len(ineqs) > lp_threshold):
            nv = poly.ndim + poly.nparam
            # prune only the inequality part against the full system
            ineqs = _lp_prune(ineqs, nv)

    # Drop the eliminated columns.
    def strip(row):
        body = [row[i] for i in keep]
        body += list(row[poly.ndim:])
        return tuple(body)

    new = Polyhedron(tuple(poly.dim_names[i] for i in keep), poly.param_names,
                     tuple(strip(r) for r in ineqs),
                     tuple(strip(e) for e in eqs))
    return new.canonical()


def project_onto(poly: Polyhedron, keep: Sequence[int], **kw) -> Polyhedron:
    drop = [i for i in range(poly.ndim) if i not in set(keep)]
    return project_out(poly, drop, **kw)


def minkowski_sum_box_exact(poly: Polyhedron, lo: Sequence, hi: Sequence,
                            **kw) -> Polyhedron:
    """Exact ``poly ⊕ Box(lo, hi)`` via lifting + projection.

    Builds {(y, u) : y - u in P, lo <= u <= hi} and projects out u.  Used as
    the *oracle* for validating §3.1 inflation; the production path never
    calls this (that is the point of the paper).
    """
    n = poly.ndim
    u_names = tuple(f"_u{i}" for i in range(n))
    lifted_dims = poly.dim_names + u_names

    def lift(row):
        a = row[:n]
        rest = row[n:]
        return tuple(a) + tuple(-c for c in a) + tuple(rest)

    box = Polyhedron.box(u_names, lo, hi, poly.param_names)

    lifted = Polyhedron(lifted_dims, poly.param_names,
                        tuple(lift(r) for r in poly.ineqs),
                        tuple(lift(e) for e in poly.eqs))
    box_l = box.add_dims(poly.dim_names, front=True)
    both = lifted.intersect(box_l)
    return project_out(both, list(range(n, 2 * n)), **kw)
