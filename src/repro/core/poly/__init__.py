"""Exact polyhedral engine (paper §3): polyhedra, projection, compression."""
from .compression import (Tiling, compress, tile_dependence,
                          tile_dependence_projection, tile_domain)
from .counting import CountingFunction, dims_to_params, make_counting_function
from .linalg import diag, eye, frac, mat, mat_inv, mat_mul, vec
from .lp import LPResult, lp_feasible, lp_max, lp_min, lp_solve
from .polyhedron import Polyhedron
from .projection import minkowski_sum_box_exact, project_onto, project_out
from .scanning import (LoopNest, clear_scan_cache, scan_cache_info,
                       shard_polyhedron)

__all__ = [
    "Polyhedron", "Tiling", "LoopNest", "CountingFunction",
    "scan_cache_info", "clear_scan_cache", "shard_polyhedron",
    "compress", "tile_domain", "tile_dependence", "tile_dependence_projection",
    "project_out", "project_onto", "minkowski_sum_box_exact",
    "dims_to_params", "make_counting_function",
    "lp_solve", "lp_feasible", "lp_min", "lp_max", "LPResult",
    "frac", "vec", "mat", "eye", "diag", "mat_mul", "mat_inv",
]
