"""Exact rational linear programming (two-phase primal simplex, Bland's rule).

Used by the polyhedron layer for:
  * feasibility / emptiness certificates,
  * redundancy removal (is constraint c implied by the rest?),
  * inclusion tests (P1 subseteq P2),
  * numeric bounds when scanning loop nests.

All arithmetic is in ``fractions.Fraction`` so there is no numerical error and
Bland's rule guarantees termination.  Problems in this codebase are small
(tens of variables, low hundreds of constraints) which exact simplex handles
comfortably.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Optional, Sequence

F0 = Fraction(0)
F1 = Fraction(1)


@dataclass
class LPResult:
    status: str  # 'optimal' | 'unbounded' | 'infeasible'
    value: Optional[Fraction] = None
    x: Optional[tuple[Fraction, ...]] = None


class _Simplex:
    """maximize c.z  s.t.  A z = b (b >= 0), z >= 0, with a known basis.

    Bland's rule (lowest-index entering / leaving) => guaranteed termination.
    ``blocked`` columns may never enter the basis (used to freeze artificials
    in phase 2).
    """

    def __init__(self, rows: list[list[Fraction]], basis: list[int]):
        self.rows = rows          # each row: coeffs + [rhs]
        self.basis = basis
        self.m = len(rows)
        self.ncol = len(rows[0]) - 1 if rows else 0
        self.obj: list[Fraction] = []
        self.blocked: set[int] = set()

    def set_objective(self, c: list[Fraction]) -> None:
        """Install objective (maximize) and price it out w.r.t. current basis."""
        self.obj = list(c) + [F0]
        for i, bi in enumerate(self.basis):
            if self.obj[bi] != 0:
                f = self.obj[bi]
                self.obj = [x - f * y for x, y in zip(self.obj, self.rows[i])]

    def pivot(self, r: int, col: int) -> None:
        pv = self.rows[r][col]
        self.rows[r] = [x / pv for x in self.rows[r]]
        prow = self.rows[r]
        for i in range(self.m):
            if i != r and self.rows[i][col] != 0:
                f = self.rows[i][col]
                self.rows[i] = [x - f * y for x, y in zip(self.rows[i], prow)]
        if self.obj and self.obj[col] != 0:
            f = self.obj[col]
            self.obj = [x - f * y for x, y in zip(self.obj, prow)]
        self.basis[r] = col

    def run(self) -> str:
        while True:
            col = next((j for j in range(self.ncol)
                        if j not in self.blocked and self.obj[j] > 0), None)
            if col is None:
                return "optimal"
            best_r, best_ratio = None, None
            for i in range(self.m):
                a = self.rows[i][col]
                if a > 0:
                    ratio = self.rows[i][-1] / a
                    if (best_ratio is None or ratio < best_ratio or
                            (ratio == best_ratio and self.basis[i] < self.basis[best_r])):
                        best_r, best_ratio = i, ratio
            if best_r is None:
                return "unbounded"
            self.pivot(best_r, col)

    def value(self) -> Fraction:
        return -self.obj[-1]

    def solution(self, n: int) -> list[Fraction]:
        x = [F0] * n
        for i, b in enumerate(self.basis):
            if b < n:
                x[b] = self.rows[i][-1]
        return x


def lp_solve(ineqs: Sequence[Sequence[Fraction]], nvar: int,
             objective: Sequence[Fraction], maximize: bool = True) -> LPResult:
    """Optimize ``objective . x`` over {x free : row[:nvar].x + row[nvar] >= 0}.

    ``ineqs`` rows have length nvar+1 (coefficients then constant term).
    """
    sign = F1 if maximize else -F1
    m = len(ineqs)
    # Free x via split x_j = z_{2j} - z_{2j+1};  a.x + c >= 0  =>  -a.x <= c
    # => standard row:  sum_j (-a_j)(z+ - z-) + slack = c.
    nz = 2 * nvar
    ncol = nz + m + m  # real pairs | slacks | artificials (allocated lazily)
    rows: list[list[Fraction]] = []
    basis: list[int] = []
    art_cols: list[int] = []
    nart = 0
    for i, row in enumerate(ineqs):
        a, const = row[:nvar], Fraction(row[nvar])
        r = []
        for j in range(nvar):
            r.append(-Fraction(a[j]))
            r.append(Fraction(a[j]))
        slack = [F0] * m
        slack[i] = F1
        r = r + slack
        if const < 0:
            r = [-x for x in r]
            const = -const
            rows.append(r)  # artificial appended after we know nart
            basis.append(-1)  # placeholder -> artificial
            art_cols.append(i)
            nart += 1
        else:
            rows.append(r)
            basis.append(nz + i)  # slack is basic
        rows[-1].append(const)

    # install artificial columns
    ncol = nz + m + nart
    k = 0
    for i in range(m):
        body, rhs = rows[i][:-1], rows[i][-1]
        art = [F0] * nart
        if basis[i] == -1:
            art[k] = F1
            basis[i] = nz + m + k
            k += 1
        rows[i] = body + art + [rhs]

    sx = _Simplex(rows, basis)

    if nart:
        phase1 = [F0] * (nz + m) + [-F1] * nart
        sx.set_objective(phase1)
        st = sx.run()
        assert st == "optimal"
        if sx.value() != 0:
            return LPResult("infeasible")
        # Pivot any artificial still in the basis out (degenerate rows).
        for i in range(sx.m):
            if sx.basis[i] >= nz + m:
                col = next((j for j in range(nz + m) if sx.rows[i][j] != 0), None)
                if col is not None:
                    sx.pivot(i, col)
        sx.blocked = set(range(nz + m, ncol))

    obj = [F0] * ncol
    for j in range(nvar):
        obj[2 * j] = sign * Fraction(objective[j])
        obj[2 * j + 1] = -sign * Fraction(objective[j])
    sx.set_objective(obj)
    st = sx.run()
    if st == "unbounded":
        return LPResult("unbounded")
    z = sx.solution(nz)
    x = tuple(z[2 * j] - z[2 * j + 1] for j in range(nvar))
    val = sum((Fraction(objective[j]) * x[j] for j in range(nvar)), F0)
    return LPResult("optimal", val, x)


def lp_feasible(ineqs: Sequence[Sequence[Fraction]], nvar: int) -> bool:
    """Is {x : a.x + c >= 0 for all rows} non-empty (over the rationals)?"""
    return lp_solve(ineqs, nvar, [F0] * nvar).status != "infeasible"


def lp_min(ineqs, nvar, objective) -> LPResult:
    return lp_solve(ineqs, nvar, objective, maximize=False)


def lp_max(ineqs, nvar, objective) -> LPResult:
    return lp_solve(ineqs, nvar, objective, maximize=True)
