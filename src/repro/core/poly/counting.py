"""Predecessor-count functions (paper §4.3).

With autodecs, the first predecessor to reach a successor task must initialize
its counted dependence with the *exact* number of predecessors.  The paper
generates, per dependence polyhedron, a function

    pred_count(T_target, params) -> int

in one of two forms, chosen by a shape heuristic:

  * an **enumerator** — a closed-form product evaluated in O(n) (cheap, but
    only valid for rectangular get-loops),
  * a **counting loop** — scan the get-loop and count (shape-insensitive, cost
    proportional to the count).

We realize both: the target tile coordinates are moved into the *parameter*
space of the polyhedron, so the per-level Fourier-Motzkin systems are computed
once at "compile time", and each call is a cheap bound evaluation.  With the
default ``compiled`` scanning backend every call runs pure integer
arithmetic (the bounds were normalized to ceil/floor-division form when the
nest was built); ``backend="fraction"`` retains the reference rational path
for the equivalence regression tests.  ``backend="numpy"`` adds
:meth:`CountingFunction.count_block`: counts for a whole block of target
tiles at once — the enumerator form becomes a few matrix products over the
coordinate block, with a scalar-compiled fallback for counting loops.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Callable, Sequence

import numpy as np

from .polyhedron import Polyhedron
from .scanning import LoopNest

F0 = Fraction(0)


def dims_to_params(poly: Polyhedron, dim_idx: Sequence[int]) -> Polyhedron:
    """Reclassify the given dims as parameters (appended after existing params).

    The polyhedron's point set is unchanged; only the scanning/counting role
    of the coordinates changes.  Used to turn Δ_T(T_s, T_t) into a family of
    source sets parameterized by the target tile.
    """
    dim_idx = sorted(set(dim_idx))
    keep = [i for i in range(poly.ndim) if i not in dim_idx]

    def conv(row):
        body = [row[i] for i in keep]
        params = list(row[poly.ndim:poly.ndim + poly.nparam])
        moved = [row[i] for i in dim_idx]
        return tuple(body + params + moved + [row[-1]])

    return Polyhedron(tuple(poly.dim_names[i] for i in keep),
                      poly.param_names + tuple(poly.dim_names[i] for i in dim_idx),
                      tuple(conv(r) for r in poly.ineqs),
                      tuple(conv(r) for r in poly.eqs)).canonical()


@dataclass
class CountingFunction:
    """Callable predecessor/successor counter with a recorded strategy."""
    nest: LoopNest
    strategy: str  # 'enumerator' | 'loop'
    # param order of nest: original params then fixed-dim coordinates.

    def __call__(self, coords: Sequence[int], params: Sequence[int] = ()) -> int:
        pv = list(params) + list(coords)
        if self.strategy == "enumerator":
            return self._enumerate(pv)
        return self.nest.count(pv)

    def _enumerate(self, pv) -> int:
        """O(n) closed form — valid only for rectangular nests."""
        if not self.nest.feasible(pv):
            return 0
        total = 1
        for level in self.nest.levels:
            lb, ub = self.nest._bounds(level, [0] * level.k, pv)
            if lb is None or ub is None:
                raise ValueError("unbounded dim in enumerator")
            if ub < lb:
                return 0
            total *= ub - lb + 1
        return total

    def points(self, coords: Sequence[int], params: Sequence[int] = ()):
        """Iterate the counted set (the paper's get/put/autodec loop body)."""
        return self.nest.iterate(list(params) + list(coords))

    def count_block(self, coords: "np.ndarray",
                    params: Sequence[int] = ()) -> "np.ndarray":
        """Counts for a ``(N, nfixed)`` block of fixed coordinates at once.

        Enumerator strategy: the closed form vectorizes into per-level bound
        evaluations over the block (one matvec per bound row) — O(rows)
        array ops total, no per-coordinate Python.  Loop strategy: falls
        back to the compiled scalar counter per row.  Values are identical
        to calling ``self(coords_i, params)`` per row.
        """
        base = [int(p) for p in params]
        nest = self.nest
        nfixed = nest.nparam - len(base)
        coords = np.asarray(coords, dtype=np.int64)
        if coords.ndim != 2:
            # -1 is ambiguous for size-0 inputs; the fixed-dim count is known
            coords = coords.reshape(-1, nfixed) if nfixed else coords.reshape(len(coords), 0)
        n = coords.shape[0]
        assert coords.shape[1] == nfixed
        if self.strategy != "enumerator":
            out = np.empty(n, dtype=np.int64)
            count = nest.count
            for i, row in enumerate(coords.tolist()):
                out[i] = count(base + row)
            return out

        def rest(par, const):
            """const + par·(params, coords) over the block -> (N,) array."""
            v = const
            for c, p in zip(par[:len(base)], base):
                if c:
                    v += c * p
            cc = np.asarray(par[len(base):], dtype=np.int64)
            if cc.size and cc.any():
                return coords @ cc + v
            return np.full(n, v, dtype=np.int64)

        total = np.ones(n, dtype=np.int64)
        feasible = np.ones(n, dtype=bool)
        if nest._infeasible:
            return np.zeros(n, dtype=np.int64)
        for par, const in nest._int_guards:
            feasible &= rest(par, const) >= 0
        for los, ups in nest._int_levels:
            lb = None
            ub = None
            # rectangular nests have no outer-dim terms (prefix is all-zero
            # in the scalar enumerator, so any stray ones contribute nothing)
            for r in los:
                v = -(rest(r.par, r.const) // r.a)
                lb = v if lb is None else np.maximum(lb, v)
            for r in ups:
                v = rest(r.par, r.const) // r.a
                ub = v if ub is None else np.minimum(ub, v)
            if lb is None or ub is None:
                raise ValueError("unbounded dim in enumerator")
            total *= np.maximum(ub - lb + 1, 0)
        total[~feasible] = 0
        return total


def make_counting_function(delta_t: Polyhedron, count_dims: Sequence[int],
                           fixed_dims: Sequence[int],
                           strategy: str = "auto",
                           backend: str = "compiled") -> CountingFunction:
    """Build ``count(fixed_coords, params) -> |{count_dims points}|``.

    ``count_dims``/``fixed_dims`` partition the dims of ``delta_t``.
    For a predecessor counter on Δ_T(T_s, T_t): count_dims = source dims,
    fixed_dims = target dims.  Strategy 'auto' applies the paper's heuristic:
    rectangular nest -> enumerator, else counting loop.  ``backend`` selects
    the scanning evaluation path (see :mod:`.scanning`).
    """
    assert sorted(list(count_dims) + list(fixed_dims)) == list(range(delta_t.ndim))
    fam = dims_to_params(delta_t, fixed_dims)
    nest = LoopNest(fam, backend=backend)
    if strategy == "auto":
        strategy = "enumerator" if nest.is_rectangular() else "loop"
    return CountingFunction(nest=nest, strategy=strategy)
