"""Predecessor-count functions (paper §4.3).

With autodecs, the first predecessor to reach a successor task must initialize
its counted dependence with the *exact* number of predecessors.  The paper
generates, per dependence polyhedron, a function

    pred_count(T_target, params) -> int

in one of two forms, chosen by a shape heuristic:

  * an **enumerator** — a closed-form product evaluated in O(n) (cheap, but
    only valid for rectangular get-loops),
  * a **counting loop** — scan the get-loop and count (shape-insensitive, cost
    proportional to the count).

We realize both: the target tile coordinates are moved into the *parameter*
space of the polyhedron, so the per-level Fourier-Motzkin systems are computed
once at "compile time", and each call is a cheap bound evaluation.  With the
default ``compiled`` scanning backend every call runs pure integer
arithmetic (the bounds were normalized to ceil/floor-division form when the
nest was built); ``backend="fraction"`` retains the reference rational path
for the equivalence regression tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Callable, Sequence

from .polyhedron import Polyhedron
from .scanning import LoopNest

F0 = Fraction(0)


def dims_to_params(poly: Polyhedron, dim_idx: Sequence[int]) -> Polyhedron:
    """Reclassify the given dims as parameters (appended after existing params).

    The polyhedron's point set is unchanged; only the scanning/counting role
    of the coordinates changes.  Used to turn Δ_T(T_s, T_t) into a family of
    source sets parameterized by the target tile.
    """
    dim_idx = sorted(set(dim_idx))
    keep = [i for i in range(poly.ndim) if i not in dim_idx]

    def conv(row):
        body = [row[i] for i in keep]
        params = list(row[poly.ndim:poly.ndim + poly.nparam])
        moved = [row[i] for i in dim_idx]
        return tuple(body + params + moved + [row[-1]])

    return Polyhedron(tuple(poly.dim_names[i] for i in keep),
                      poly.param_names + tuple(poly.dim_names[i] for i in dim_idx),
                      tuple(conv(r) for r in poly.ineqs),
                      tuple(conv(r) for r in poly.eqs)).canonical()


@dataclass
class CountingFunction:
    """Callable predecessor/successor counter with a recorded strategy."""
    nest: LoopNest
    strategy: str  # 'enumerator' | 'loop'
    # param order of nest: original params then fixed-dim coordinates.

    def __call__(self, coords: Sequence[int], params: Sequence[int] = ()) -> int:
        pv = list(params) + list(coords)
        if self.strategy == "enumerator":
            return self._enumerate(pv)
        return self.nest.count(pv)

    def _enumerate(self, pv) -> int:
        """O(n) closed form — valid only for rectangular nests."""
        if not self.nest.feasible(pv):
            return 0
        total = 1
        for level in self.nest.levels:
            lb, ub = self.nest._bounds(level, [0] * level.k, pv)
            if lb is None or ub is None:
                raise ValueError("unbounded dim in enumerator")
            if ub < lb:
                return 0
            total *= ub - lb + 1
        return total

    def points(self, coords: Sequence[int], params: Sequence[int] = ()):
        """Iterate the counted set (the paper's get/put/autodec loop body)."""
        return self.nest.iterate(list(params) + list(coords))


def make_counting_function(delta_t: Polyhedron, count_dims: Sequence[int],
                           fixed_dims: Sequence[int],
                           strategy: str = "auto",
                           backend: str = "compiled") -> CountingFunction:
    """Build ``count(fixed_coords, params) -> |{count_dims points}|``.

    ``count_dims``/``fixed_dims`` partition the dims of ``delta_t``.
    For a predecessor counter on Δ_T(T_s, T_t): count_dims = source dims,
    fixed_dims = target dims.  Strategy 'auto' applies the paper's heuristic:
    rectangular nest -> enumerator, else counting loop.  ``backend`` selects
    the scanning evaluation path (see :mod:`.scanning`).
    """
    assert sorted(list(count_dims) + list(fixed_dims)) == list(range(delta_t.ndim))
    fam = dims_to_params(delta_t, fixed_dims)
    nest = LoopNest(fam, backend=backend)
    if strategy == "auto":
        strategy = "enumerator" if nest.is_rectangular() else "loop"
    return CountingFunction(nest=nest, strategy=strategy)
