"""Parametric graph cache: one compile, many sizes, warm answers.

The paper's premise is that a *parametric* polyhedral program is compiled
once and instantiated at many sizes.  The scanning layer already honors
that one level down — compiled scan/count functions are cached by
canonical polyhedron (``scan_cache_info``) — but every ``index_graph`` /
``synthesize_indexed`` call still re-ran the scans per ``params``.
:class:`GraphCache` extends the caching one level up: finished graph
products, keyed by ``(canonical program fingerprint, params)``.

Per key the cache holds up to five products, filled lazily in dependency
order and each returned by reference on a warm hit:

  ``ig``        :class:`~repro.core.edt.taskgraph.IndexedGraph`
  ``schedule``  :class:`~repro.core.edt.wavefront.IndexedSchedule`
  ``dg``        :class:`~repro.core.edt.device.DeviceGraph`  (pack_graph)
  ``ds``        :class:`~repro.core.edt.device.DeviceSchedule` (pack_schedule)
  ``fo``        fused tile-origin columns (``fused.pack_origins``)

Eviction is LRU over whole entries, bounded by
:class:`~repro.core.edt.config.CachePolicy` — ``max_entries`` and a hard
``max_bytes`` budget over every stored array.  ``graph_cache_info()``
exposes hit/miss/eviction counters across all live caches.

Incremental re-materialization
------------------------------
When a request misses but a cached entry exists at params differing only
in values, the cache asks each scan unit (statement tile nests, joint
dependence nests — :meth:`TiledTaskGraph.scan_units`) whether the changed
parameters are *outer-only* for it
(:meth:`~repro.core.poly.scanning.LoopNest.outer_only_params`: zero
coefficient in every inner-level bound row).  For such a unit, rows at a
fixed outer coordinate are identical across the change, so the unit's new
scan is stitched: the outer-range overlap is sliced out of the donor's
arrays (dependence rows are rebuilt from the donor graph via
``IndexedGraph.dep_spans`` — nothing extra is stored), and only the new
outer blocks are scanned, through the same ``__slo``/``__shi`` block
nests the shard engine uses (:meth:`LoopNest.block_nest`).  Units that
fail the test (or whose outer range is unbounded/infeasible) are
re-scanned in full — reuse is per-unit, and the merged result is
byte-identical to a cold scan by the same partition argument that makes
sharded merges exact (``docs/sharding.md``).
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .config import CachePolicy, ExecutionConfig

#: Live caches, for module-level introspection (weakly held).
_CACHES: "weakref.WeakSet" = weakref.WeakSet()


def _norm_value(name, value):
    """One param value, normalized to a plain Python scalar.

    ``{"N": np.int64(512)}`` (a sharded merge), ``{"N": 512}`` (a direct
    call), and the JSON-parsed values ``edt_serve`` feeds in must all land
    on ONE cache entry — so numpy scalars collapse to their Python
    equivalents before keying.  Unhashable values (arrays, lists, dicts)
    are rejected here with the offending name instead of surfacing as an
    opaque ``unhashable type`` deep inside a dict probe.
    """
    if isinstance(value, (bool, np.bool_)):
        return bool(value)
    if isinstance(value, (int, np.integer)):
        return int(value)
    if isinstance(value, (float, np.floating)):
        v = float(value)
        return int(v) if v.is_integer() else v
    try:
        hash(value)
    except TypeError:
        raise TypeError(
            f"parameter {name!r} has unhashable value {value!r} "
            f"({type(value).__name__}); cache keys need scalar parameter "
            "values") from None
    return value


def _norm_params(params: dict) -> dict:
    """The params dict with every value scalar-normalized (see
    :func:`_norm_value`); entries store this form so donor comparisons and
    incremental stitching never see mixed numpy/Python scalar types."""
    return {k: _norm_value(k, v) for k, v in params.items()}


def _params_key(params: dict) -> tuple:
    return tuple(sorted(_norm_params(params).items()))


def _sched_nbytes(s) -> int:
    return int(s.level_of.nbytes + sum(lv.nbytes for lv in s.levels))


def _dg_nbytes(dg) -> int:
    return int(dg.indptr.nbytes + dg.succ.nbytes + dg.dec_src.nbytes
               + dg.dec_ptr.nbytes + dg.pred_n.nbytes)


def _ds_nbytes(ds) -> int:
    # ds.levels/level_of alias the IndexedSchedule's arrays — counted there
    return int(ds.order.nbytes + ds.task_ptr.nbytes + ds.lvl_tgt.nbytes
               + ds.edge_ptr.nbytes)


@dataclass
class _Entry:
    params: dict
    ig: object = None
    schedule: object = None
    dg: object = None
    ds: object = None
    fo: object = None        # fused tile-origin columns (i32[n+1, ndim])
    bytes: int = field(default=0)


class GraphCache:
    """LRU + byte-budget cache of graph products per (fingerprint, params).

    Thread-safe bookkeeping (an ``RLock`` guards the entry map and
    counters); materialization itself runs unlocked, so concurrent cold
    misses on different keys proceed in parallel.  Concurrent misses on
    the *same* key each materialize and the first store wins — callers
    that need exactly-once cold fills coalesce one level up
    (:class:`~repro.core.edt.service.ScheduleService`).
    """

    def __init__(self, policy: Optional[CachePolicy] = None):
        self.policy = policy if policy is not None else CachePolicy()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._lock = threading.RLock()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.incremental_hits = 0
        self.units_reused = 0
        _CACHES.add(self)

    # ------------------------------------------------------------ plumbing
    def _key(self, graph, params: dict) -> tuple:
        return (graph.fingerprint(), _params_key(params))

    def _evict_locked(self) -> None:
        policy = self.policy
        while self._entries and (
                len(self._entries) > policy.max_entries
                or (policy.max_bytes is not None
                    and self._bytes > policy.max_bytes)):
            _, ent = self._entries.popitem(last=False)
            self._bytes -= ent.bytes
            self.evictions += 1

    def _store(self, key: tuple, params: dict, name: str, value, nbytes: int):
        """Install a product (first writer wins); returns the cached value."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                ent = _Entry(params=_norm_params(params))
                self._entries[key] = ent
            if getattr(ent, name) is None:
                setattr(ent, name, value)
                ent.bytes += nbytes
                self._bytes += nbytes
            else:
                value = getattr(ent, name)
            self._entries.move_to_end(key)
            self._evict_locked()
            return value

    def _lookup(self, key: tuple, name: str):
        """Warm probe: returns the product and counts the hit/miss."""
        with self._lock:
            ent = self._entries.get(key)
            val = getattr(ent, name) if ent is not None else None
            if val is not None:
                self.hits += 1
                self._entries.move_to_end(key)
            else:
                self.misses += 1
            return val

    def peek(self, graph, params: dict, name: str = "schedule"):
        """Non-mutating warm check (no counters, no LRU touch)."""
        with self._lock:
            ent = self._entries.get(self._key(graph, params))
            return getattr(ent, name) if ent is not None else None

    #: product kind -> the entry fields that make up its return value
    #: (in return order; every field present ⇒ the whole answer is warm).
    PRODUCT_FIELDS = {"graph": ("ig",), "schedule": ("ig", "schedule"),
                     "packed": ("dg", "ds"), "fused": ("dg", "ds", "fo")}

    def lookup_product(self, graph, params: dict, kind: str):
        """Atomic warm hit for a whole product ``kind``, or ``None``.

        One probe under the cache lock returns every array the product
        needs (``graph`` → ig, ``schedule`` → (ig, schedule), ``packed`` →
        (dg, ds), ``fused`` → (dg, ds, fo)) — so a caller holding the
        result can never lose a component to a concurrent eviction, unlike
        a ``peek`` followed by a re-fetch.  A full hit counts one hit and
        touches the LRU; any missing component returns ``None`` without
        counting (the cold fill that follows counts its own misses).
        """
        fields = self.PRODUCT_FIELDS[kind]
        key = self._key(graph, params)
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            vals = tuple(getattr(ent, f) for f in fields)
            if any(v is None for v in vals):
                return None
            self.hits += 1
            self._entries.move_to_end(key)
            return vals[0] if len(vals) == 1 else vals

    # ------------------------------------------------------------ products
    def graph(self, graph, params: dict,
              cfg: Optional[ExecutionConfig] = None):
        """The cached :class:`IndexedGraph`, materializing on a miss.

        A miss first tries incremental re-materialization from a cached
        sibling entry (same fingerprint, params differing only in values)
        before falling back to a cold scan under ``cfg``.
        """
        cfg = cfg if cfg is not None else ExecutionConfig(cache=self.policy)
        if not self.policy.enabled:
            with self._lock:
                self.misses += 1
            return graph._index_graph_cfg(params, cfg)
        key = self._key(graph, params)
        ig = self._lookup(key, "ig")
        if ig is not None:
            return ig
        donor = None
        if self.policy.incremental:
            with self._lock:
                donor = self._find_donor_locked(key, graph)
        if donor is not None:
            ig = self._incremental(graph, donor, params, cfg)
        if ig is None:
            ig = graph._index_graph_cfg(params, cfg)
        return self._store(key, params, "ig", ig, ig.nbytes)

    def schedule(self, graph, params: dict,
                 cfg: Optional[ExecutionConfig] = None):
        """``(IndexedGraph, IndexedSchedule)``, leveling at most once."""
        from .wavefront import schedule_from_graph
        ig = self.graph(graph, params, cfg)
        if not self.policy.enabled:
            return ig, schedule_from_graph(ig)
        key = self._key(graph, params)
        sched = self._lookup(key, "schedule")
        if sched is None:
            s = schedule_from_graph(ig)
            sched = self._store(key, params, "schedule", s, _sched_nbytes(s))
        return ig, sched

    def packed_graph(self, graph, params: dict,
                     cfg: Optional[ExecutionConfig] = None):
        """The cached :class:`DeviceGraph` (``pack_graph`` columns)."""
        from .device import pack_graph
        ig = self.graph(graph, params, cfg)
        if not self.policy.enabled:
            return pack_graph(ig)
        key = self._key(graph, params)
        dg = self._lookup(key, "dg")
        if dg is None:
            dg = pack_graph(ig)
            dg = self._store(key, params, "dg", dg, _dg_nbytes(dg))
        return dg

    def packed(self, graph, params: dict,
               cfg: Optional[ExecutionConfig] = None):
        """``(DeviceGraph, DeviceSchedule)`` — the sub-ms warm-hit unit.

        A warm hit is two dictionary probes returning device-ready arrays
        by reference; nothing is scanned, leveled, or packed.
        """
        from .device import pack_schedule
        ig, sched = self.schedule(graph, params, cfg)
        dg = self.packed_graph(graph, params, cfg)
        if not self.policy.enabled:
            return dg, pack_schedule(ig, sched)
        key = self._key(graph, params)
        ds = self._lookup(key, "ds")
        if ds is None:
            ds = pack_schedule(ig, sched)
            ds = self._store(key, params, "ds", ds, _ds_nbytes(ds))
        return dg, ds

    def fused(self, graph, params: dict,
              cfg: Optional[ExecutionConfig] = None):
        """``(DeviceGraph, DeviceSchedule, origin columns)`` — everything
        the fused executor reads, each by reference on a warm hit.

        The origin columns are packed from the cached index graph and the
        graph's own tile sizes (both already under this entry's
        fingerprint, which hashes the tilings), so the product needs no
        extra key material; its bytes count against the entry budget like
        every other product.
        """
        from .fused import graph_tile, pack_origins
        dg, ds = self.packed(graph, params, cfg)
        if not self.policy.enabled:
            ig = self.graph(graph, params, cfg)
            return dg, ds, pack_origins(ig, graph_tile(graph))
        key = self._key(graph, params)
        fo = self._lookup(key, "fo")
        if fo is None:
            ig = self.graph(graph, params, cfg)
            fo = pack_origins(ig, graph_tile(graph))
            fo = self._store(key, params, "fo", fo, int(fo.nbytes))
        return dg, ds, fo

    # --------------------------------------------------------- incremental
    def _find_donor_locked(self, key: tuple, graph):
        """Most-recent entry of the same program at different param values."""
        fp, _ = key
        names = set(graph.param_names)
        for k in reversed(self._entries):
            if k == key or k[0] != fp:
                continue
            ent = self._entries[k]
            if (ent.ig is not None and ent.ig.dep_spans is not None
                    and set(ent.params) == names):
                return ent.params, ent.ig
        return None

    def _incremental(self, graph, donor, params: dict,
                     cfg: ExecutionConfig):
        """Stitch a new index graph from a donor entry, unit by unit.

        Returns ``None`` when no unit is reusable (callers cold-scan).
        """
        from .shard import EDGES, ShardedScans, TILES
        donor_params, donor_ig = donor
        changed = frozenset(
            i for i, nm in enumerate(graph.param_names)
            if donor_params[nm] != params[nm])
        if not changed:
            return None
        pv = graph._pv(params)
        dpv = graph._pv(donor_params)
        tiles: dict = {}
        raw: dict = {}
        reused = 0
        for kind, ukey, nest in graph.scan_units():
            ok = nest.ndim > 0 and changed <= nest.outer_only_params()
            if ok:
                ob = nest.outer_bounds(dpv)
                nb = nest.outer_bounds(pv)
                ok = ob is not None and nb is not None
            if kind == TILES:
                if ok:
                    old = dict(donor_ig.stmt_blocks)[ukey]
                    tiles[ukey], did = _stitch_unit(nest, old, ob, nb, pv)
                    reused += did
                else:
                    tiles[ukey] = nest.iterate_array(pv)
            else:
                assert kind == EDGES
                if ok:
                    old = _dep_raw_rows(graph, donor_ig, ukey)
                    raw[ukey], did = _stitch_unit(nest, old, ob, nb, pv)
                    reused += did
                # not reusable: omitted → _edge_indices cold-scans the unit
        if not reused:
            return None
        ig = graph._index_graph_cfg(
            params, cfg, scans=ShardedScans(tiles=tiles, edges_raw=raw))
        with self._lock:
            self.incremental_hits += 1
            self.units_reused += reused
        return ig

    # -------------------------------------------------------- introspection
    def info(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "max_entries": self.policy.max_entries,
                "max_bytes": self.policy.max_bytes,
                "enabled": self.policy.enabled,
                "incremental": self.policy.incremental,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "incremental_hits": self.incremental_hits,
                "units_reused": self.units_reused,
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0


def _stitch_unit(nest, old_rows: "np.ndarray", ob, nb, pv):
    """One unit's new scan: donor overlap slice + fresh outer blocks.

    ``old_rows`` is the donor's full scan of this unit (rows lex-sorted,
    column 0 = the outer coordinate, so the overlap is a ``searchsorted``
    slice).  New outer ranges scan through the unit's ``__slo``/``__shi``
    block nest — the same restricted scans the shard workers run, so
    concatenating [new-prefix, overlap, new-suffix] in outer order is
    byte-identical to a full scan.  Returns ``(rows, reused_flag)``.
    """
    lo_n, hi_n = nb
    ov_lo, ov_hi = max(ob[0], lo_n), min(ob[1], hi_n)
    if ov_hi < ov_lo:       # disjoint outer ranges: nothing to reuse
        return nest.iterate_array(pv), 0
    bn = nest.block_nest()
    parts = []
    if lo_n < ov_lo:
        parts.append(bn.iterate_array(list(pv) + [lo_n, ov_lo - 1]))
    col0 = old_rows[:, 0]
    s = int(np.searchsorted(col0, ov_lo, "left"))
    e = int(np.searchsorted(col0, ov_hi, "right"))
    parts.append(old_rows[s:e])
    if ov_hi < hi_n:
        parts.append(bn.iterate_array(list(pv) + [ov_hi + 1, hi_n]))
    return (np.concatenate(parts) if len(parts) > 1 else parts[0]), 1


def _dep_raw_rows(graph, ig, dep_idx: int) -> "np.ndarray":
    """A dependence's joint (src, tgt) coordinate rows, rebuilt from the
    cached graph — ``dep_spans`` slices the edge arrays, the statement
    blocks gather the coordinates.  Self pairs stay excluded (the
    downstream filter is idempotent); row order is the joint-scan lex
    order, so column 0 ascends."""
    td = graph.tiled_deps[dep_idx]
    start, stop = ig.dep_spans[dep_idx]
    src = ig.edge_src[start:stop]
    tgt = ig.edge_tgt[start:stop]
    off = 0
    base: dict = {}
    for name, arr in ig.stmt_blocks:
        base[name] = (off, arr)
        off += arr.shape[0]
    so, sarr = base[td.dep.src]
    to, tarr = base[td.dep.tgt]
    return np.concatenate([sarr[src - so], tarr[tgt - to]], axis=1)


def graph_cache_info() -> dict:
    """Aggregate hit/miss/byte counters across every live GraphCache."""
    caches = [c.info() for c in list(_CACHES)]
    return {
        "caches": len(caches),
        "entries": sum(c["entries"] for c in caches),
        "bytes": sum(c["bytes"] for c in caches),
        "hits": sum(c["hits"] for c in caches),
        "misses": sum(c["misses"] for c in caches),
        "evictions": sum(c["evictions"] for c in caches),
        "incremental_hits": sum(c["incremental_hits"] for c in caches),
        "units_reused": sum(c["units_reused"] for c in caches),
    }
