"""Synchronization-overhead atlas: the paper's Table-2 evaluation, measured.

The paper prices each §2 synchronization model on five overhead axes —
sequential start-up, in-flight task and dependence management, space for
sync objects, and garbage collection — as asymptotic classes over the task
count ``n``, edge count ``e``, and maximum ready-set size ``r``.  This
module turns the instrumented models of :mod:`.syncmodels` into that
table: a synthetic workload sweep over

* **program class** — the diamond grid (single dominator, the prescribed
  model's worst case), a dense-LA Cholesky DAG, a time-skewed stencil, and
  banded fan-out "trees" whose depth / width / fan-out are independent
  knobs (all from :data:`repro.core.programs.PROGRAMS`),
* **size** — an ascending parameter ladder per workload; the reference
  curves n(s), e(s), r(s) are measured from the materialized graph, never
  assumed,
* **task grain** — the simulated task duration relative to the fixed
  master-op cost (fine grain exposes sequential start-up; coarse grain
  hides it in the makespan),
* **sync model** — all six registered models.

Every measured run is validated (:func:`~.syncmodels.validate_order`:
exactly-once, dependence-respecting) before its counters are recorded, and
the output is plain row dicts with string keys — the regime maps CI tracks
as JSON (``benchmarks/bench_sync_overheads.py``, schema v8; see
``docs/sync_atlas.md``).

:func:`fit_rows` fits each counter's growth across the size ladder against
the candidate classes ``{1, r, n, e, n^2}`` (least squares in log space
with a free constant) and checks the winner against the paper's expected
class, treating classes the workload cannot distinguish (e.g. ``n`` vs
``e`` when edges grow linearly with tasks, or ``r`` vs ``n`` on a
fixed-depth band sweep) as equivalent — the distinguishability test is
data-driven, from the measured reference curves themselves.

:func:`crossover` records where this sweep overlaps the real execution
engines: host ``simulate_indexed`` vs :class:`~.device.DeviceExecutor`
replay vs two-rank :func:`~.distributed.run_distributed`, per task across
an ascending size ladder, with the first size at which each engine beats
the host marked as the crossover point.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Optional

from ..poly import Tiling
from .syncmodels import MODELS, run_model, validate_order
from .taskgraph import TiledTaskGraph


def _program(name: str):
    # Imported at call time: programs.py itself imports the edt package
    # (taskgraph), so a module-level import here would be circular.
    from ..programs import PROGRAMS
    return PROGRAMS[name]()

# The five Table-2 overhead axes, as keyed in ``Counters.summary()``.
ATLAS_COUNTERS = ("startup_ops", "spatial_peak", "inflight_tasks_peak",
                  "inflight_deps_peak", "garbage_peak")

# Candidate asymptotic classes: constant, max ready-set size, tasks,
# edges, tasks squared.
CLASSES = ("1", "r", "n", "e", "n2")

SETUP_COST = 0.01      # master-op cost (the grain denominator)
GRAINS = (0.2, 1.0, 5.0)
SMOKE_GRAINS = (1.0,)
# The overhead sweep runs with workers that always bind: the paper's r-class
# peaks (ready-backlog-shaped counters like counted/autodec garbage) are
# realized only when the machine is narrower than the frontier, so tasks
# actually queue.  The engine crossover uses a realistic width instead.
WORKERS = 2
CROSSOVER_WORKERS = 8


@dataclass(frozen=True)
class AtlasWorkload:
    """One program class in the sweep: a size ladder plus its knobs."""
    program: str                  # PROGRAMS registry key
    family: str                   # graph | dense_la | stencil | tree
    tiles: tuple                  # tile sizes (unit tiles: task = point)
    sizes: tuple                  # ascending param dicts (full ladder)
    smoke_sizes: tuple            # ascending param dicts (smoke ladder)
    fanout: Optional[int] = None  # band radius for the tree family


WORKLOADS = (
    AtlasWorkload("diamond", "graph", (1, 1),
                  ({"K": 6}, {"K": 12}, {"K": 24}),
                  ({"K": 4}, {"K": 8})),
    AtlasWorkload("cholesky_like", "dense_la", (1, 1, 1),
                  ({"N": 5}, {"N": 8}, {"N": 12}),
                  # three smoke points: a 2-point dense-LA ladder is too
                  # short to separate r from e at these sizes
                  ({"N": 3}, {"N": 5}, {"N": 7})),
    AtlasWorkload("stencil1d", "stencil", (1, 1),
                  ({"T": 6, "N": 6}, {"T": 12, "N": 12}, {"T": 24, "N": 24}),
                  ({"T": 4, "N": 4}, {"T": 8, "N": 8})),
    # Fixed depth, growing width: a pure wavefront-width sweep at two
    # dependence fan-outs (band radius 2 vs 8).
    AtlasWorkload("fanout2", "tree", (1, 1),
                  ({"L": 6, "W": 8}, {"L": 6, "W": 24}, {"L": 6, "W": 64}),
                  ({"L": 4, "W": 4}, {"L": 4, "W": 10}), fanout=2),
    AtlasWorkload("fanout8", "tree", (1, 1),
                  ({"L": 6, "W": 8}, {"L": 6, "W": 24}, {"L": 6, "W": 64}),
                  ({"L": 4, "W": 4}, {"L": 4, "W": 10}), fanout=8),
)

# Paper Table 2, in this harness's measurable symbols.  Values are the
# expected asymptotic class of each counter's peak, read as an UPPER BOUND:
# the checker fails a fit only when the measured class grows strictly
# faster than every expected class (up to what the workload's own reference
# curves can distinguish, :func:`_indistinct`).  A measured peak *below*
# its table class is recorded (``relation == "below"``) but is not a
# failure — e.g. autodec's in-flight dependence peak is bounded by
# workers x fan-out on a narrow machine, well under its r bound.
#
# Notes tying the symbols back to the table: the prescribed master declares
# every task and edge before anything runs (start-up n+e ~ e); tags and
# autodec start in O(1); counted start-up is the n counter initializations.
# Space/in-flight track edges for the tag models and the prescribed graph,
# tasks for counted (one counter per task, live until its task starts) and
# for autodec-without-src (the master preschedules all n concurrently), but
# only the ready frontier r for autodec-with-src.  tags2 space is still e,
# not n: the tags are one-per-producer but the outstanding get records are
# per-edge.  Garbage drains continuously everywhere except tags2, whose
# one-tag-per-producer objects are disposable only at graph completion
# (~n dead tags); prescribed garbage (satisfied-but-unconsumed edges) is
# the edge-cut of the completion frontier — Θ(r) on local-dependence
# programs but up to Θ(e) on dense-LA / wide-band DAGs, so its bound is e.
EXPECTED = {
    "prescribed": {"startup_ops": ("e",), "spatial_peak": ("e",),
                   "inflight_tasks_peak": ("n",),
                   "inflight_deps_peak": ("e",), "garbage_peak": ("e",)},
    "tags1": {"startup_ops": ("1",), "spatial_peak": ("e",),
              "inflight_tasks_peak": ("n",),
              "inflight_deps_peak": ("e",), "garbage_peak": ("1",)},
    "tags2": {"startup_ops": ("1",), "spatial_peak": ("e",),
              "inflight_tasks_peak": ("n",),
              "inflight_deps_peak": ("e",), "garbage_peak": ("n",)},
    "counted": {"startup_ops": ("n",), "spatial_peak": ("n",),
                "inflight_tasks_peak": ("n",),
                "inflight_deps_peak": ("n",), "garbage_peak": ("r",)},
    "autodec": {"startup_ops": ("1",), "spatial_peak": ("r",),
                "inflight_tasks_peak": ("r",),
                "inflight_deps_peak": ("r",), "garbage_peak": ("r",)},
    "autodec_nosrc": {"startup_ops": ("1",), "spatial_peak": ("n",),
                      "inflight_tasks_peak": ("r",),
                      "inflight_deps_peak": ("n",), "garbage_peak": ("r",)},
}

# Growth-rate order of the candidate classes on this module's workloads:
# r <= n always (the frontier is a subset of the tasks), and every program
# in WORKLOADS has e >= n - 1 (connected DAGs), so the order is total.
_RANK = {"1": 0, "r": 1, "n": 2, "e": 3, "n2": 4}


@dataclass
class Instance:
    """One (workload, params) point: the graph and its measured shape."""
    workload: AtlasWorkload
    graph: TiledTaskGraph
    params: dict
    n_tasks: int
    n_edges: int
    width: int            # r: max tasks simultaneously ready
    depth: int            # wavefront levels
    max_fanout: int       # max out-degree

    @property
    def size_label(self) -> str:
        return ",".join(f"{k}={v}" for k, v in self.params.items())


def build_instances(workload: AtlasWorkload,
                    smoke: bool = False) -> list[Instance]:
    """Materialize the workload's size ladder and measure its shape.

    The reference curves (n, e, r, depth, fan-out) come from the explicit
    graph — the fit layer never assumes a formula for them.
    """
    g = TiledTaskGraph(_program(workload.program),
                       {"S": Tiling(workload.tiles)})
    out = []
    for params in (workload.smoke_sizes if smoke else workload.sizes):
        m = g.materialize(params)
        ws = m.wavefronts()
        out.append(Instance(
            workload=workload, graph=g, params=dict(params),
            n_tasks=len(m.tasks), n_edges=m.n_edges,
            width=max((len(w) for w in ws), default=0), depth=len(ws),
            max_fanout=m.max_out_degree()))
    return out


def measure(inst: Instance, model: str, grain: float = 1.0,
            workers: int = WORKERS) -> dict:
    """One atlas row: run ``model`` on the instance, validated, flattened.

    ``grain`` is the simulated task duration; the master-op cost stays at
    :data:`SETUP_COST`, so grain/SETUP_COST is the task-to-setup cost
    ratio the start-up columns are priced against.
    """
    res = run_model(model, inst.graph, inst.params, workers=workers,
                    task_dur=grain, setup_cost=SETUP_COST)
    validate_order(inst.graph, inst.params, res, task_dur=grain)
    w = inst.workload
    row = {"program": w.program, "family": w.family, "model": model,
           "size": inst.size_label, "params": dict(inst.params),
           "grain": grain, "workers": workers,
           "n_tasks": inst.n_tasks, "n_edges": inst.n_edges,
           "width": inst.width, "depth": inst.depth,
           "max_fanout": inst.max_fanout, "band": w.fanout}
    row.update(_counter_fields(res))
    return row


def _counter_fields(res) -> dict:
    s = res.counters.summary()
    s["makespan"] = round(s["makespan"], 4)
    return s


# ------------------------------------------------------------------ fitting
def _logs(vals) -> list[float]:
    # Zero-valued counters are clamped to 0.5 so log space stays defined;
    # all-zero series short-circuit to class "1" before reaching here.
    return [math.log(max(float(v), 0.5)) for v in vals]


def reference_curves(insts: list[Instance]) -> dict[str, list[float]]:
    return {"1": [1.0] * len(insts),
            "r": [float(i.width) for i in insts],
            "n": [float(i.n_tasks) for i in insts],
            "e": [float(max(i.n_edges, 1)) for i in insts],
            "n2": [float(i.n_tasks) ** 2 for i in insts]}


def fit_class(ys, refs: dict[str, list[float]]) -> dict:
    """Best asymptotic class for the series ``ys`` over the size ladder.

    Least squares in log space with a free multiplicative constant per
    candidate; the winner is the minimal-residual class, with near-ties
    (within 0.05 log-residual) resolved toward the candidate whose
    constant is closest to 1 — so a counter that *equals* n beats one that
    merely grows like it.
    """
    if max(ys) == 0:
        return {"cls": "1", "scale": 0.0, "resid": 0.0}
    ly = _logs(ys)
    cands = []
    for cls in CLASSES:
        lc = _logs(refs[cls])
        la = sum(a - b for a, b in zip(ly, lc)) / len(ly)
        resid = math.sqrt(sum((a - b - la) ** 2
                              for a, b in zip(ly, lc)) / len(ly))
        cands.append((resid, abs(la), cls, math.exp(la)))
    cands.sort()
    best_resid = cands[0][0]
    near = sorted(c for c in cands if c[0] <= best_resid + 0.05)
    _, _, cls, scale = min(near, key=lambda c: c[1])
    return {"cls": cls, "scale": round(scale, 4),
            "resid": round(best_resid, 4)}


def _indistinct(refs: dict[str, list[float]], c1: str, c2: str,
                tol: float = 0.2) -> bool:
    """True when the workload's own curves cannot separate two classes.

    Two candidates are equivalent for fitting exactly when their log-ratio
    is (nearly) constant across the ladder — e.g. n vs e on any program
    whose edge count grows linearly with tasks, or r vs n on a fixed-depth
    width sweep.  Measured, not declared per program.
    """
    if c1 == c2:
        return True
    d = [a - b for a, b in zip(_logs(refs[c1]), _logs(refs[c2]))]
    mean = sum(d) / len(d)
    return max(abs(x - mean) for x in d) < tol


def fit_rows(rows: list[dict], insts_by_program: dict[str, list[Instance]],
             grain: float = 1.0) -> list[dict]:
    """Fit every (program, model, counter) series measured at ``grain``.

    Each output row records the fitted class, the paper's expected classes,
    the relation of fit to bound (``match`` up to the workload's own
    distinguishability, ``below``, or ``above``), and ``ok`` — the Table-2
    classes are upper bounds, so only ``above`` fails.
    """
    out = []
    for program, insts in insts_by_program.items():
        refs = reference_curves(insts)
        labels = [i.size_label for i in insts]
        for model in MODELS:
            series = {r["size"]: r for r in rows
                      if r["program"] == program and r["model"] == model
                      and r["grain"] == grain}
            if len(series) != len(labels):
                continue
            for counter in ATLAS_COUNTERS:
                ys = [series[lbl][counter] for lbl in labels]
                fit = fit_class(ys, refs)
                expected = EXPECTED[model][counter]
                if any(_indistinct(refs, fit["cls"], e) for e in expected):
                    relation = "match"
                elif _RANK[fit["cls"]] < min(_RANK[e] for e in expected):
                    relation = "below"
                else:
                    relation = "above"
                out.append({"program": program, "model": model,
                            "counter": counter, "values": ys,
                            "cls": fit["cls"], "scale": fit["scale"],
                            "resid": fit["resid"],
                            "expected": list(expected),
                            "relation": relation,
                            "ok": relation != "above"})
    return out


def growth_rows(rows: list[dict], grain: float = 1.0) -> list[dict]:
    """Growth factors between the smallest and largest size per model.

    The task ratio comes from the *measured* ``n_tasks`` (not a per-program
    closed form), and genuinely-zero counters are reported as such: 0 -> 0
    is factor 1.0, 0 -> b is factor None (born at scale) — never masked by
    a max(1, ...) floor.
    """
    by_pm: dict[tuple, list[dict]] = {}
    for r in rows:
        if r["grain"] != grain:
            continue
        by_pm.setdefault((r["program"], r["model"]), []).append(r)
    out = []
    for (program, model), rs in by_pm.items():
        rs = sorted(rs, key=lambda r: r["n_tasks"])
        lo, hi = rs[0], rs[-1]
        g: dict = {"program": program, "model": model,
                   "size_lo": lo["size"], "size_hi": hi["size"],
                   "task_factor": round(hi["n_tasks"] / lo["n_tasks"], 2),
                   "edge_factor": round(hi["n_edges"] / max(1, lo["n_edges"]), 2),
                   "width_factor": round(hi["width"] / max(1, lo["width"]), 2)}
        for counter in ATLAS_COUNTERS:
            a, b = lo[counter], hi[counter]
            if a == 0:
                g[counter] = 1.0 if b == 0 else None
            else:
                g[counter] = round(b / a, 2)
        out.append(g)
    return out


def sweep(smoke: bool = False, grains: Optional[tuple] = None,
          workers: int = WORKERS, emit=None) -> dict:
    """The full atlas: rows + fits + growth factors, ready for JSON.

    The default grain (1.0) runs at every size (the asymptotic ladder);
    the other grains run at the largest size only (the grain axis prices
    start-up dominance, not growth).
    """
    if grains is None:
        grains = SMOKE_GRAINS if smoke else GRAINS
    say = emit or (lambda *a, **k: None)
    rows: list[dict] = []
    insts_by_program: dict[str, list[Instance]] = {}
    say("program,family,model,size,grain,n_tasks,n_edges,width,"
        + ",".join(ATLAS_COUNTERS) + ",makespan")
    for w in WORKLOADS:
        insts = build_instances(w, smoke=smoke)
        insts_by_program[w.program] = insts
        for inst in insts:
            for model in MODELS:
                for grain in grains:
                    if grain != 1.0 and inst is not insts[-1]:
                        continue
                    row = measure(inst, model, grain=grain, workers=workers)
                    rows.append(row)
                    say(f"{row['program']},{row['family']},{model},"
                        f"{row['size']},{grain},{row['n_tasks']},"
                        f"{row['n_edges']},{row['width']},"
                        + ",".join(str(row[c]) for c in ATLAS_COUNTERS)
                        + f",{row['makespan']}")
    fits = fit_rows(rows, insts_by_program)
    growth = growth_rows(rows)
    return {"rows": rows, "fits": fits, "growth": growth,
            "counters": list(ATLAS_COUNTERS), "classes": list(CLASSES),
            "grains": list(grains), "workers": workers,
            "fit_failures": [f for f in fits if not f["ok"]]}


# -------------------------------------------------------- engine crossover
# Where the sweep overlaps the real engines: the counted model is what
# DeviceExecutor and run_distributed execute, so the same graphs are priced
# per task through the host Sim, the device replay sweep, and a two-rank
# inline distributed run, across an ascending ladder.
CROSSOVER_SIZES = ({"T": 4, "N": 32}, {"T": 8, "N": 64}, {"T": 16, "N": 128})
CROSSOVER_SMOKE = ({"T": 4, "N": 24},)
CROSSOVER_TILES = (2, 2, 2)


def crossover(smoke: bool = False, workers: int = CROSSOVER_WORKERS,
              emit=None) -> dict:
    """Per-task engine cost across sizes + first size each engine wins.

    Rows: ``{program, size, n_tasks, path, seconds, per_task_us,
    verified}`` with ``path`` in {host_sim, device_replay,
    distributed_inline_2}.  The device path is warm (second run: dispatch
    cost, not jit); a missing/broken jax stack records a skip row instead
    of failing the atlas.  ``points`` maps each non-host path to the first
    size label where it beat the host, or None within this ladder.
    """
    import numpy as np

    from .wavefront import simulate_indexed, synthesize_indexed

    say = emit or (lambda *a, **k: None)
    sizes = CROSSOVER_SMOKE if smoke else CROSSOVER_SIZES
    g = TiledTaskGraph(_program("jacobi2d"), {"S": Tiling(CROSSOVER_TILES)},
                       backend="numpy")
    rows: list[dict] = []
    say("program,size,n_tasks,path,seconds,per_task_us,verified")

    def row(size_label, n, path, seconds, verified, skipped=None):
        r = {"program": "jacobi2d", "size": size_label, "n_tasks": n,
             "path": path, "seconds": round(seconds, 4),
             "per_task_us": round(1e6 * seconds / max(1, n), 3),
             "verified": bool(verified)}
        if skipped:
            r["skipped"] = skipped
        rows.append(r)
        say(f"jacobi2d,{size_label},{n},{path},{r['seconds']},"
            f"{r['per_task_us']},{r['verified']}")
        return r

    for params in sizes:
        label = ",".join(f"{k}={v}" for k, v in params.items())
        ig, sched = synthesize_indexed(g, params)
        t0 = time.perf_counter()
        sim = simulate_indexed(sched, workers=workers)
        host_s = time.perf_counter() - t0
        host_order = np.asarray(sim.exec_order)
        row(label, ig.n, "host_sim", host_s, len(sim.exec_order) == ig.n)

        try:
            from .device import DeviceExecutor
            dev = DeviceExecutor(ig, schedule=sched)
            dev.run()                              # cold: jit + transfer
            t0 = time.perf_counter()
            run = dev.run()                        # warm: dispatch cost
            dev_s = time.perf_counter() - t0
            ok = np.array_equal(run.exec_order, host_order)
            row(label, ig.n, "device_replay", dev_s, ok)
        except Exception as e:  # noqa: BLE001 — record the skip, keep going
            row(label, ig.n, "device_replay", 0.0, False, skipped=repr(e))

        try:
            from .distributed import run_distributed
            t0 = time.perf_counter()
            drun = run_distributed(ig, ranks=2, engine="numpy",
                                   transport="inline")
            dist_s = time.perf_counter() - t0
            ok = np.array_equal(drun.level_of, sched.level_of)
            row(label, ig.n, "distributed_inline_2", dist_s, ok)
        except Exception as e:  # noqa: BLE001
            row(label, ig.n, "distributed_inline_2", 0.0, False,
                skipped=repr(e))

    points: dict[str, Optional[str]] = {}
    host = {r["size"]: r["per_task_us"] for r in rows
            if r["path"] == "host_sim"}
    for path in ("device_replay", "distributed_inline_2"):
        points[path] = next(
            (r["size"] for r in rows
             if r["path"] == path and r["verified"]
             and r["per_task_us"] < host[r["size"]]), None)
        say(f"# crossover {path}: {points[path]}")
    return {"rows": rows, "points": points}


# Package-level aliases: ``sweep`` / ``crossover`` are too generic to
# re-export bare from :mod:`repro.core.edt`.
atlas_sweep = sweep
atlas_crossover = crossover
