"""Emit the paper's generated-code forms (Figures 3, 4, 5) for inspection.

The *executable* counterparts live in ``taskgraph.py`` (iterators) and
``syncmodels.py`` (runtime behavior); this module renders the same polyhedra
as human-readable pseudo-C so examples and docs can show exactly what the
compiler "generates" for each synchronization model.
"""
from __future__ import annotations

from ..poly import LoopNest
from ..poly.counting import dims_to_params
from .taskgraph import TiledTaskGraph


def _dep_loop(graph: TiledTaskGraph, td, fix: str) -> str:
    """Render the get ('src' fixed=target) or put ('tgt' fixed=source) loop."""
    ns = graph.tilings[td.dep.src].ndim
    if fix == "target":   # get loop: scan sources given my coords
        fixed = list(range(ns, td.delta_t.ndim))
    else:                 # put/autodec loop: scan targets given my coords
        fixed = list(range(ns))
    fam = dims_to_params(td.delta_t, fixed)
    return LoopNest(fam).pretty_loops()


def emit_prescribed(graph: TiledTaskGraph) -> str:
    """Fig 3: task-creation loops + declarative dependence loops."""
    out = ["// ---- prescribed model (Fig 3): master sets everything up ----"]
    for name, nest in graph.tile_nests.items():
        out.append(f"// create tasks of statement '{name}'")
        out.append(nest.pretty_loops().replace("body(", f"task_init({name!r}, "))
    for td in graph.tiled_deps:
        out.append(f"// declare dependences {td.dep.name}")
        out.append(LoopNest(td.delta_t).pretty_loops()
                   .replace("body(", "declare_dependence("))
    return "\n".join(out)


def emit_tags(graph: TiledTaskGraph, method: int = 2) -> str:
    """Fig 4: per-task gets on predecessors, puts for (self|successors)."""
    out = [f"// ---- tags model, Method {method} (Fig 4) ----"]
    for name in graph.program.statements:
        out.append(f"task {name}(iT...):")
        for td in graph._in[name]:
            out.append(f"  // gets on {td.dep.name}")
            for line in _dep_loop(graph, td, "target").splitlines()[:-1]:
                out.append("  " + line)
            out.append("    get(tag(src))" if method == 2
                       else "    get(tag(src, iT))")
        out.append("  compute(iT)")
        if method == 2:
            out.append("  put(tag(iT))")
        else:
            for td in graph._out[name]:
                out.append(f"  // puts on {td.dep.name}")
                for line in _dep_loop(graph, td, "source").splitlines()[:-1]:
                    out.append("  " + line)
                out.append("    put(tag(iT, tgt))")
    return "\n".join(out)


def emit_autodec(graph: TiledTaskGraph) -> str:
    """Fig 5: pred-count function + autodec loop; master preschedules roots."""
    out = ["// ---- autodec model (Fig 5) ----"]
    strategies = graph.pred_count_strategies()
    for name in graph.program.statements:
        out.append(f"int pred_count_{name}(iT...):  // §4.3")
        for td in graph._in[name]:
            strat = strategies[td.dep.name]
            out.append(f"  // {td.dep.name}: strategy = {strat}")
            if strat == "enumerator":
                out.append("  n += closed_form(iT)   // O(dims) evaluation")
            else:
                for line in _dep_loop(graph, td, "target").splitlines()[:-1]:
                    out.append("  " + line)
                out.append("    n++;")
        out.append("  return n;")
    for name in graph.program.statements:
        out.append(f"task {name}(iT...):")
        out.append("  compute(iT)")
        for td in graph._out[name]:
            out.append(f"  // autodec successors via {td.dep.name}")
            for line in _dep_loop(graph, td, "source").splitlines()[:-1]:
                out.append("  " + line)
            out.append(f"    autodec(tgt, pred_count_{td.dep.tgt})")
    out.append("// master: preschedule(t) for all t — O(1) sequential start-up")
    return "\n".join(out)
