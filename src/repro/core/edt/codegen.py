"""Emit the paper's generated-code forms (Figures 3, 4, 5) for inspection.

The *executable* counterparts live in ``taskgraph.py`` (iterators) and
``syncmodels.py`` (runtime behavior); this module renders the same polyhedra
as human-readable pseudo-C so examples and docs can show exactly what the
compiler "generates" for each synchronization model.  :func:`emit_fused`
renders the counted model's *fused* device form — counter sweep plus tile
body in one program — whose executable counterpart is
:class:`~repro.core.edt.fused.FusedExecutor`.
"""
from __future__ import annotations

from ..poly import LoopNest
from ..poly.counting import dims_to_params
from .taskgraph import TiledTaskGraph


def _dep_loop(graph: TiledTaskGraph, td, fix: str) -> str:
    """Render the get ('src' fixed=target) or put ('tgt' fixed=source) loop."""
    ns = graph.tilings[td.dep.src].ndim
    if fix == "target":   # get loop: scan sources given my coords
        fixed = list(range(ns, td.delta_t.ndim))
    else:                 # put/autodec loop: scan targets given my coords
        fixed = list(range(ns))
    fam = dims_to_params(td.delta_t, fixed)
    return LoopNest(fam).pretty_loops()


def emit_prescribed(graph: TiledTaskGraph) -> str:
    """Fig 3: task-creation loops + declarative dependence loops."""
    out = ["// ---- prescribed model (Fig 3): master sets everything up ----"]
    for name, nest in graph.tile_nests.items():
        out.append(f"// create tasks of statement '{name}'")
        out.append(nest.pretty_loops().replace("body(", f"task_init({name!r}, "))
    for td in graph.tiled_deps:
        out.append(f"// declare dependences {td.dep.name}")
        out.append(LoopNest(td.delta_t).pretty_loops()
                   .replace("body(", "declare_dependence("))
    return "\n".join(out)


def emit_tags(graph: TiledTaskGraph, method: int = 2) -> str:
    """Fig 4: per-task gets on predecessors, puts for (self|successors)."""
    out = [f"// ---- tags model, Method {method} (Fig 4) ----"]
    for name in graph.program.statements:
        out.append(f"task {name}(iT...):")
        for td in graph._in[name]:
            out.append(f"  // gets on {td.dep.name}")
            for line in _dep_loop(graph, td, "target").splitlines()[:-1]:
                out.append("  " + line)
            out.append("    get(tag(src))" if method == 2
                       else "    get(tag(src, iT))")
        out.append("  compute(iT)")
        if method == 2:
            out.append("  put(tag(iT))")
        else:
            for td in graph._out[name]:
                out.append(f"  // puts on {td.dep.name}")
                for line in _dep_loop(graph, td, "source").splitlines()[:-1]:
                    out.append("  " + line)
                out.append("    put(tag(iT, tgt))")
    return "\n".join(out)


def emit_autodec(graph: TiledTaskGraph) -> str:
    """Fig 5: pred-count function + autodec loop; master preschedules roots."""
    out = ["// ---- autodec model (Fig 5) ----"]
    strategies = graph.pred_count_strategies()
    for name in graph.program.statements:
        out.append(f"int pred_count_{name}(iT...):  // §4.3")
        for td in graph._in[name]:
            strat = strategies[td.dep.name]
            out.append(f"  // {td.dep.name}: strategy = {strat}")
            if strat == "enumerator":
                out.append("  n += closed_form(iT)   // O(dims) evaluation")
            else:
                for line in _dep_loop(graph, td, "target").splitlines()[:-1]:
                    out.append("  " + line)
                out.append("    n++;")
        out.append("  return n;")
    for name in graph.program.statements:
        out.append(f"task {name}(iT...):")
        out.append("  compute(iT)")
        for td in graph._out[name]:
            out.append(f"  // autodec successors via {td.dep.name}")
            for line in _dep_loop(graph, td, "source").splitlines()[:-1]:
                out.append("  " + line)
            out.append(f"    autodec(tgt, pred_count_{td.dep.tgt})")
    out.append("// master: preschedule(t) for all t — O(1) sequential start-up")
    return "\n".join(out)


def emit_fused(graph: TiledTaskGraph, body: str = None) -> str:
    """The fused counted-sync device sweep: decrement + tile body, one loop.

    Pseudo-code for what :class:`~repro.core.edt.fused.FusedExecutor`
    compiles — the level loop of the replay sweep with the stencil body
    (``repro.kernels.stencils.SPECS``) inlined between the validation
    gathers and the counter decrement.  ``body`` defaults to the program's
    registered name.
    """
    from ...kernels.stencils import SPECS
    name = body or getattr(graph.program, "name", "")
    if name not in SPECS:
        raise ValueError(f"no stencil body registered for {name!r}; "
                        f"known: {sorted(SPECS)}")
    spec = SPECS[name]
    (tiling,) = graph.tilings.values()
    tile = tiling.sizes
    seq = [f"l{k}" for k in range(spec.space) if spec.seq_space[k]]
    par = [f"l{k}" for k in range(spec.space) if not spec.seq_space[k]]
    out = [f"// ---- fused counted model: device sweep + {name} body ----",
           f"// state: u[2*S+1]  (S = N^{spec.space} sites; parity buffers "
           "p = t & 1,",
           "//         slot 2S = zero halo; masked writes drop) — "
           "docs/device_exec.md",
           "for level in range(depth):                   // one fori_loop, "
           "never host",
           "  ids  = order[task_ptr[level] : +w_pad]     // fixed-width "
           "slice, sentinel-padded",
           "  chk  = indeg[ids] != 0 if lane < width     // validation (a): "
           "not ready",
           "  chk += indeg[next_ids] == 0                // validation (b): "
           "early ready",
           "  org  = origin[ids]                         // tile origins "
           "(t0, x0...)"]
    steps = " * ".join(str(g) for g in tile)
    out.append(f"  // tile body: {steps} points/tile, taps={len(spec.taps)}"
               f" (dt,off,w), seq dims: t{',' if seq else ''}{','.join(seq)}")
    out.append(f"  for tt in range({tile[0]}):"
               "                        // local time: sequential")
    ind = "    "
    for d in seq:
        out.append(f"{ind}for {d} in range(g):                     "
                   "// Gauss-Seidel dim: sequential")
        ind += "  "
    if par:
        out.append(f"{ind}vmap over ({', '.join(par)}):               "
                   "// parallel spatial lanes")
        ind += "  "
    out.append(f"{ind}t, s = org.t + tt, org.x + l - t        "
               "// unskew: site = x - t")
    out.append(f"{ind}mask = 0 <= t < T and s in [0, N)^d     "
               "// = domain membership")
    for dt, off, w in spec.taps:
        buf = "p" if dt == 0 else "1-p"
        out.append(f"{ind}acc += {w:g} * u[{buf}, s + {off}]"
                   f"{'':<{max(1, 14 - 3 * len(off))}}// dt={dt}, halo reads 0")
    out.append(f"{ind}u[p, s] = acc if mask                   "
               "// distinct slots per level (proof: fused.py)")
    out += ["  // counted-sync decrement: this level's out-edges, one "
            "contiguous slice",
            "  tgts = lvl_tgt[edge_ptr[level] : +e_pad]",
            "  indeg[tgts] -= 1                           // scatter-add, "
            "slot n swallows pads",
            "chk += sum(indeg != 0)                       // validation (c): "
            "undrained",
            "// chk == 0 proves the schedule IS the counted-model execution"]
    return "\n".join(out)
