"""Unified execution configuration: one frozen config, one session handle.

PRs 1-6 accreted per-call knobs — ``backend=`` on graph construction, then
``shards=``/``parallel=``/``pool=`` (PR 4) and ``faults=``/``recovery=``
(PR 6) threaded positionally through half a dozen signatures, drifting
along the way (``roots()`` never grew the fault kwargs).  This module
replaces the knob plumbing with two objects:

* :class:`ExecutionConfig` — a frozen dataclass naming every execution
  knob once (generation backend, shard fan-out, pool, fault plan, retry
  policy, cache policy).  Every graph-level API accepts ``config=``; the
  legacy kwargs keep working through :func:`resolve_execution`, which
  builds the equivalent config and emits a :class:`DeprecationWarning`
  once per call-site.
* :class:`Session` — a handle that owns the process pool, a
  :class:`~repro.core.edt.cache.GraphCache`, and the config defaults.
  Graph products requested through a session are cached by
  ``(parametric-program fingerprint, params)`` and the pool amortizes
  across calls — the serving posture (see ``docs/service.md``).

The module is import-light on purpose (no numpy/jax, no graph types at
module scope): ``taskgraph``/``wavefront``/``device`` all import it, and it
reaches back into them lazily.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from dataclasses import dataclass
from typing import Any, Iterator, Optional

#: Names of the per-call kwargs superseded by :class:`ExecutionConfig`.
LEGACY_KWARGS = ("shards", "parallel", "pool", "faults", "recovery")


class _Unset:
    """Sentinel distinguishing "not passed" from an explicit ``None``."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<unset>"


UNSET = _Unset()

_DEPRECATION_MSG = (
    "legacy execution kwargs ({names}) are deprecated; pass "
    "config=ExecutionConfig(...) or session=Session(...) instead "
    "(see docs/backends.md, migration section)")


@dataclass(frozen=True)
class CachePolicy:
    """Eviction and reuse policy for a :class:`~repro.core.edt.cache.GraphCache`.

    ``max_bytes`` is a hard budget over every cached array (graphs,
    schedules, packed device columns); ``max_entries`` bounds the LRU
    independently.  ``incremental`` enables outer-param re-materialization
    (stitch reusable outer-block scans from a cached neighbor instead of
    re-scanning from scratch); ``enabled=False`` turns the cache into a
    pass-through (every request materializes).
    """

    max_entries: int = 32
    max_bytes: Optional[int] = 2**30   # fits the ≥1M-task flagship warm set
    incremental: bool = True
    enabled: bool = True


@dataclass(frozen=True)
class ExecutionConfig:
    """Every execution knob, named once, immutable.

    ``backend`` selects the scanning backend when a graph is *built*
    through :meth:`Session.graph` (graphs fix their backend at
    construction; per-call configs leave it untouched).  ``shards`` /
    ``parallel`` / ``pool`` drive the sharded generation engine exactly as
    the old kwargs did; ``faults`` / ``recovery`` are the PR-6 robustness
    knobs, now reaching every API uniformly (including ``roots()``, which
    previously dropped them).  ``cache`` is the policy a :class:`Session`
    builds its :class:`~repro.core.edt.cache.GraphCache` from.
    """

    backend: str = "compiled"
    shards: Optional[int] = None
    parallel: bool = False
    pool: Optional[Any] = None
    faults: Optional[Any] = None          # repro.core.edt.faults.FaultPlan
    recovery: Optional[Any] = None        # repro.core.edt.recovery.RetryPolicy
    cache: CachePolicy = CachePolicy()

    def replace(self, **kw) -> "ExecutionConfig":
        return dataclasses.replace(self, **kw)

    def resolve_shards(self) -> int:
        """Effective shard count (0 = in-process); mirrors the old
        ``_resolve_shards``: ``parallel=True`` means one shard per core,
        an explicit ``shards=`` always wins."""
        if self.shards is None and self.parallel:
            return os.cpu_count() or 1
        return int(self.shards or 0)


#: Shared default — the in-process, cache-enabled baseline.
DEFAULT_CONFIG = ExecutionConfig()


def resolve_execution(config: Optional[ExecutionConfig],
                      session: Optional["Session"],
                      legacy: Optional[dict] = None,
                      stacklevel: int = 4):
    """Collapse ``config=`` / ``session=`` / legacy kwargs to one config.

    Returns ``(config, session_or_None)``.  Legacy kwargs (any value that
    is not :data:`UNSET`) build an equivalent :class:`ExecutionConfig` and
    emit a :class:`DeprecationWarning` attributed to the caller's call-site
    (so the default warning filter reports each site once); mixing them
    with the new kwargs is a :class:`TypeError`, as is passing both
    ``config=`` and ``session=``.
    """
    used = {k: v for k, v in (legacy or {}).items() if v is not UNSET}
    if used:
        if config is not None or session is not None:
            raise TypeError(
                "pass either config=/session= or the legacy kwargs "
                f"({', '.join(sorted(used))}), not both")
        warnings.warn(
            _DEPRECATION_MSG.format(
                names=", ".join(f"{k}=" for k in sorted(used))),
            DeprecationWarning, stacklevel=stacklevel)
        return ExecutionConfig(**used), None
    if config is not None and session is not None:
        raise TypeError("pass config= or session=, not both")
    if session is not None:
        return session.runtime_config(), session
    return (config if config is not None else DEFAULT_CONFIG), None


class Session:
    """Owns the pool, the graph cache, and the config defaults.

    The serving-side handle: one session amortizes one
    ``ProcessPoolExecutor`` and one :class:`~repro.core.edt.cache.GraphCache`
    across every request, so repeated ``index_graph``/``schedule`` calls at
    the same ``(program, params)`` are warm dictionary hits instead of
    fresh polyhedral scans.  Usable as a context manager; ``close()``
    shuts down a pool the session created (never one injected via
    ``config.pool``).

        with Session(ExecutionConfig(backend="numpy", shards=4)) as s:
            ig, sched = s.schedule(graph, {"T": 32, "N": 512})   # cold
            ig2, _ = s.schedule(graph, {"T": 32, "N": 512})      # warm hit
    """

    def __init__(self, config: Optional[ExecutionConfig] = None, **overrides):
        cfg = config if config is not None else ExecutionConfig()
        if overrides:
            cfg = cfg.replace(**overrides)
        self.config = cfg
        from .cache import GraphCache   # deferred: cache imports graph types
        self.cache = GraphCache(cfg.cache)
        self._pool = cfg.pool
        self._own_pool = False

    # ------------------------------------------------------------- plumbing
    def pool(self):
        """The session's executor pool, created lazily and owned if so."""
        if self._pool is None:
            from concurrent.futures import ProcessPoolExecutor
            n = self.config.resolve_shards() or (os.cpu_count() or 1)
            self._pool = ProcessPoolExecutor(
                max_workers=max(1, min(n, os.cpu_count() or 1)))
            self._own_pool = True
        return self._pool

    def runtime_config(self) -> ExecutionConfig:
        """The per-call config: session defaults + the session's pool."""
        cfg = self.config
        if cfg.resolve_shards() > 1 and cfg.pool is None:
            cfg = cfg.replace(pool=self.pool())
        return cfg

    def close(self) -> None:
        if self._own_pool and self._pool is not None:
            self._pool.shutdown()
        self._pool = None
        self._own_pool = False

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------ graph products
    def graph(self, program, tilings, method: str = "inflate"):
        """Build a :class:`TiledTaskGraph` on the session's backend."""
        from .taskgraph import TiledTaskGraph
        return TiledTaskGraph(program, tilings, method=method,
                              backend=self.config.backend)

    def index_graph(self, graph, params: dict):
        """Cached :meth:`TiledTaskGraph.index_graph` (cold miss materializes
        with the session's shards/pool/recovery)."""
        return self.cache.graph(graph, params, self.runtime_config())

    def schedule(self, graph, params: dict):
        """Cached ``(IndexedGraph, IndexedSchedule)`` — synthesize once."""
        return self.cache.schedule(graph, params, self.runtime_config())

    def packed(self, graph, params: dict):
        """Cached ``(DeviceGraph, DeviceSchedule)`` device columns."""
        return self.cache.packed(graph, params, self.runtime_config())

    def fused_packed(self, graph, params: dict):
        """Cached ``(DeviceGraph, DeviceSchedule, origin columns)`` for the
        fused executor — a warm hit packs nothing."""
        return self.cache.fused(graph, params, self.runtime_config())

    def materialize(self, graph, params: dict):
        """Uncached dict-graph materialization under the session config."""
        return graph._materialize_cfg(params, self.runtime_config())

    def roots(self, graph, params: dict) -> Iterator:
        """Roots under the session config; sharded runs reuse the cached
        index graph instead of re-scanning."""
        cfg = self.runtime_config()
        if cfg.resolve_shards() > 1:
            return graph._roots_indexed(self.index_graph(graph, params))
        return graph._roots_cfg(params, cfg)

    def synthesize(self, graph, params: dict):
        """Labelled wavefront schedule, leveled from the cached index graph."""
        from .wavefront import _synthesize_from_ig
        return _synthesize_from_ig(self.index_graph(graph, params))

    def executor(self, graph, params: dict, *, replay: bool = True,
                 use_pallas: bool = False, interpret: Optional[bool] = None):
        """A :class:`DeviceExecutor` over the cached packed arrays.

        ``replay=True`` packs (and validates) the cached schedule;
        ``replay=False`` builds the discover-mode executor (optionally on
        the pallas step).
        """
        from .device import DeviceExecutor
        ig = self.index_graph(graph, params)
        if replay:
            dg, ds = self.packed(graph, params)
            return DeviceExecutor(ig, packed=(dg, ds))
        dg = self.cache.packed_graph(graph, params, self.runtime_config())
        return DeviceExecutor(ig, packed=(dg, None), use_pallas=use_pallas,
                              interpret=interpret)

    def distributed(self, graph, params: dict, *, ranks: int = 2, **kw):
        """Distributed counted-sync run over the cached index graph —
        ``kw`` forwards ``engine=``/``transport=``/``timeout=``... to
        :func:`~repro.core.edt.distributed.run_distributed`; the session's
        ``faults``/``recovery`` knobs arm injection and retry (see
        ``docs/distributed.md``)."""
        from .distributed import run_distributed
        ig = self.index_graph(graph, params)
        return run_distributed(ig, ranks=ranks,
                               config=self.runtime_config(), **kw)

    def fused_executor(self, graph, params: dict, *, replay: bool = True,
                       **kw):
        """A :class:`~repro.core.edt.fused.FusedExecutor` over the cached
        fused packed arrays (body/tile inferred from the graph; ``kw``
        forwards ``state=``/``dtype=``/``validate=``/``use_pallas=``...).
        """
        from .fused import FusedExecutor, graph_tile
        ig = self.index_graph(graph, params)
        dg, ds, fo = self.fused_packed(graph, params)
        kw.setdefault("body", getattr(graph.program, "name", "") or None)
        kw.setdefault("tile", graph_tile(graph))
        return FusedExecutor(ig, params,
                             packed=(dg, ds if replay else None, fo), **kw)
