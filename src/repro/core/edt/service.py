"""Async schedule service: warm answers from the cache, cold fills coalesced.

"A Tale of Three Runtimes" argues generated EDT code must be competitive
with hand-tuned runtimes *end to end* — for a serving workload that means
the answer to "give me the frontier stream / packed schedule for program P
at size N" has to be sub-millisecond once warm.  :class:`ScheduleService`
is that front end, sitting on a :class:`~repro.core.edt.config.Session`:

* **Warm hits** are answered inline on the event loop from the session's
  :class:`~repro.core.edt.cache.GraphCache` — two dictionary probes, no
  thread hop, no pool, no scans.
* **Cold misses** run on a small thread pool (the event loop never
  blocks on a scan) under the session's
  :class:`~repro.core.edt.config.ExecutionConfig` — so a sharded config
  fans the polyhedral scans across the session's *process* pool with the
  PR-6 recovery semantics (retry + backoff + pool rebuild,
  ``docs/robustness.md``) exactly as a direct ``index_graph`` call would.
* **Concurrent requests for the same key coalesce**: the first request
  registers an in-flight future before it ever awaits, later arrivals
  await that future, and exactly one materialization runs no matter how
  many clients ask (asserted by ``tests/test_graph_cache.py``).

``launch/edt_serve.py`` wires this into a CLI;
``benchmarks/bench_service.py`` prices cold vs warm latency and
concurrent-client throughput.
"""
from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, Optional

from .cache import _params_key
from .config import ExecutionConfig, Session

#: product kinds the service answers (the cache's product-field map is the
#: authority on which stored arrays make each one warm).
_KINDS = ("graph", "schedule", "packed")


class ScheduleService:
    """Async batched front end over one session's graph cache.

    Construct around an existing :class:`Session` (shared cache/pool) or
    let the service own one built from ``config=``.  All request methods
    are coroutines and must run on a single event loop (the in-flight
    table relies on the loop's run-to-completion scheduling for its
    check-then-register atomicity).
    """

    def __init__(self, session: Optional[Session] = None, *,
                 config: Optional[ExecutionConfig] = None,
                 max_workers: int = 2):
        if session is not None and config is not None:
            raise TypeError("pass session= or config=, not both")
        self.session = session if session is not None else Session(config)
        self._own_session = session is None
        self._closed = False
        self._inflight: dict = {}
        self._exec = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="edt-serve")
        self.requests = 0
        self.warm = 0
        self.cold = 0
        self.coalesced = 0

    # ------------------------------------------------------------ requests
    async def index_graph(self, graph, params: dict):
        """The :class:`IndexedGraph` for ``(graph, params)``."""
        return await self._get(graph, params, "graph")

    async def schedule(self, graph, params: dict):
        """``(IndexedGraph, IndexedSchedule)`` for ``(graph, params)``."""
        return await self._get(graph, params, "schedule")

    async def packed(self, graph, params: dict):
        """``(DeviceGraph, DeviceSchedule)`` — the device-ready columns."""
        return await self._get(graph, params, "packed")

    async def frontiers(self, graph, params: dict) -> AsyncIterator:
        """The frontier stream: one int64 id array per wavefront level.

        The schedule resolves once (warm or coalesced-cold), then levels
        stream without further cache traffic — the async spelling of
        driving ``simulate_indexed`` level by level.
        """
        _, sched = await self._get(graph, params, "schedule")
        for level in sched.levels:
            yield level

    async def batch(self, graph, params_list, kind: str = "schedule"):
        """Resolve many sizes of one program concurrently (one result per
        request, same order).  Duplicate keys coalesce to one fill."""
        return await asyncio.gather(
            *(self._get(graph, p, kind) for p in params_list))

    # ------------------------------------------------------------ internals
    def _fill(self, graph, params: dict, kind: str):
        cache, cfg = self.session.cache, self.session.runtime_config()
        if kind == "graph":
            return cache.graph(graph, params, cfg)
        if kind == "schedule":
            return cache.schedule(graph, params, cfg)
        return cache.packed(graph, params, cfg)

    async def _get(self, graph, params: dict, kind: str):
        if self._closed:
            raise RuntimeError("ScheduleService is closed")
        self.requests += 1
        cache = self.session.cache
        # warm: one atomic probe returns the whole product — never touches
        # the pool or the executor.  (A peek-then-refetch pair would race
        # eviction: the entry can vanish between the two, silently turning
        # the "inline hit" into a full cold materialization ON the loop.)
        got = cache.lookup_product(graph, params, kind)
        if got is not None:
            self.warm += 1
            return got
        key = (graph.fingerprint(), _params_key(params), kind)
        fut = self._inflight.get(key)
        if fut is not None:
            self.coalesced += 1
            return await fut
        # cold: register the in-flight future synchronously (no await
        # between the miss check and this line), then materialize off-loop
        self.cold += 1
        loop = asyncio.get_running_loop()
        fut = loop.run_in_executor(
            self._exec, self._fill, graph, dict(params), kind)
        self._inflight[key] = fut
        try:
            return await fut
        finally:
            self._inflight.pop(key, None)

    # ---------------------------------------------------------- lifecycle
    def stats(self) -> dict:
        return {
            "requests": self.requests,
            "warm": self.warm,
            "cold": self.cold,
            "coalesced": self.coalesced,
            "hit_rate": (self.warm + self.coalesced) / max(1, self.requests),
            "inflight": len(self._inflight),
            "cache": self.session.cache.info(),
        }

    def close(self) -> None:
        """Drain in-flight fills, then tear down — idempotent.

        New requests are refused first (``_get`` checks ``_closed``), then
        the thread pool shuts down with ``wait=True`` — every registered
        in-flight fill runs entirely on that pool, so the shutdown IS the
        drain: when it returns, no fill can still be using the session, and
        an owned session (and its process pool) is safe to close under it.
        Clients already awaiting a drained future resolve normally.
        """
        if self._closed:
            return
        self._closed = True
        self._exec.shutdown(wait=True)
        self._inflight.clear()
        if self._own_session:
            self.session.close()

    async def __aenter__(self) -> "ScheduleService":
        return self

    async def __aexit__(self, *exc) -> None:
        self.close()
