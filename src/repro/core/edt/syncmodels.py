"""The paper's §2 synchronization models, instrumented (Table 2).

Every model executes the same :class:`TiledTaskGraph` on the :class:`Sim`
substrate and is measured on the five overhead axes.  The generated-code
structure follows §4 exactly:

* ``prescribed``     — OCR-style Method 1: a master (dominator) creates every
                       task and declares every dependence before execution.
* ``tags1``          — one tag per dependence; get/put loops; one-use tags.
* ``tags2``          — one tag per predecessor task ([27]); tags disposable
                       only at graph completion.
* ``counted``        — master initializes every task's counter using the
                       §4.3 predecessor-count function, then lets completions
                       decrement.
* ``autodec``        — the paper's proposal ("w/ src"): master preschedules
                       only the statically-computed root set; the first
                       predecessor to decrement a successor creates it.
* ``autodec_nosrc``  — "w/o src": the root set is not known statically; the
                       master preschedules *all* tasks, concurrently with
                       execution (still O(1) sequential start-up).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .executor import Counters, Sim
from .taskgraph import TaskId, TiledTaskGraph


@dataclass
class RunResult:
    model: str
    counters: Counters
    order: list  # [(task, start_time)]
    n_tasks: int
    n_edges: Optional[int] = None

    def started(self) -> list:
        return [t for t, _ in self.order]


Hook = Optional[Callable[[TaskId], None]]


def _succ_list(graph: TiledTaskGraph, task: TaskId, params) -> list[TaskId]:
    return list(graph.successors(task, params))


# --------------------------------------------------------------------------
def run_prescribed(graph: TiledTaskGraph, params: dict, workers: int = 4,
                   task_dur: float = 1.0, setup_cost: float = 0.01,
                   on_execute: Hook = None) -> RunResult:
    g = graph.materialize(params)  # the O(n^2) explicit representation
    sim = Sim(workers, task_dur, setup_cost)
    C = sim.counters
    remaining = dict(g.pred_n)
    in_satisfied: dict[TaskId, int] = {t: 0 for t in g.tasks}
    started: set[TaskId] = set()

    def make_runner(t: TaskId):
        def start_side_effects():
            # GC: input dependence objects freed when the task starts.
            n_in = g.pred_n[t]
            C.garbage.dec(in_satisfied[t])
            C.spatial.dec(n_in)
            C.inflight_tasks.dec()
            started.add(t)
            if on_execute:
                on_execute(t)

        def completion():
            for s in g.succ[t]:
                # satisfy edge object
                C.inflight_deps.dec()
                C.garbage.inc()   # dead until target starts
                in_satisfied[s] += 1
                remaining[s] -= 1
                if remaining[s] == 0:
                    sim.make_ready(s, lambda s=s: completion_of[s]())
            return None

        return start_side_effects, completion

    completion_of: dict[TaskId, Callable] = {}
    start_of: dict[TaskId, Callable] = {}
    for t in g.tasks:
        st, co = make_runner(t)
        start_of[t], completion_of[t] = st, co

    ops = []
    for t in g.tasks:  # create every task
        ops.append(lambda t=t: C.inflight_tasks.inc())
    for t in g.tasks:  # declare every dependence edge
        for _ in g.succ[t]:
            def declare():
                C.spatial.inc()
                C.inflight_deps.inc()
            ops.append(declare)

    sim.run_master(ops, gate_after_all=True)

    # once the gate opens, zero-pred tasks become ready
    def seed():
        for t in g.tasks:
            if g.pred_n[t] == 0:
                sim.make_ready(t, completion_of[t])
    sim.at(len(ops) * setup_cost, seed)

    order = _install_start_hook(sim, start_of)
    sim.run()
    return RunResult("prescribed", C, order, len(g.tasks), g.n_edges)


def _install_start_hook(sim: Sim, start_of: dict[TaskId, Callable]) -> list:
    """Run per-task start side effects at dispatch time (GC-at-start etc.).

    Uses the first-class :attr:`Sim.on_start` hook — the side effects run
    inside the real dispatch loop (exactly-once guard, worker accounting,
    error handling all apply), so they can never drift from it.  Returns
    the ``[(task, start_time)]`` list the model's :class:`RunResult`
    reports; ``start_of`` may keep growing after installation (the autodec
    models register tasks as they fire).
    """
    order: list = []

    def on_start(key) -> None:
        order.append((key, sim.now))
        fn = start_of.get(key)
        if fn is not None:
            fn()

    sim.on_start = on_start
    return order


# --------------------------------------------------------------------------
def _run_tags(graph: TiledTaskGraph, params: dict, per_dep_tags: bool,
              workers: int, task_dur: float, setup_cost: float,
              on_execute: Hook) -> RunResult:
    sim = Sim(workers, task_dur, setup_cost)
    C = sim.counters
    table: dict = {}            # tag key -> 'present'
    pending: dict = {}          # tag key -> list of waiting tasks
    waiting_n: dict[TaskId, int] = {}
    tag_consumers_left: dict = {}  # tags2 garbage tracking
    n_tasks = 0

    all_tasks = list(graph.tasks(params))
    n_tasks = len(all_tasks)
    succs = {t: _succ_list(graph, t, params) for t in all_tasks}
    preds: dict[TaskId, list[TaskId]] = {t: [] for t in all_tasks}
    for t, ss in succs.items():
        for s in ss:
            preds[s].append(t)

    start_of: dict[TaskId, Callable] = {}

    def tag_key(src: TaskId, dst: TaskId):
        return (src, dst) if per_dep_tags else src

    def make_task(t: TaskId):
        def on_scheduled():
            # the task issues its gets (asynchronously)
            n_wait = 0
            for p in preds[t]:
                k = tag_key(p, t)
                if table.get(k):
                    _consume(k, t)
                else:
                    pending.setdefault(k, []).append(t)
                    C.inflight_deps.inc()   # outstanding get record
                    C.spatial.inc()
                    n_wait += 1
            waiting_n[t] = n_wait
            if n_wait == 0:
                sim.make_ready(t, completion)

        def start_side_effects():
            C.inflight_tasks.dec()
            if on_execute:
                on_execute(t)

        def completion():
            if per_dep_tags:
                for s in succs[t]:
                    _put(tag_key(t, s), t)
            elif succs[t]:
                # one tag per producer ([27]): a single put serves every
                # consumer; the key is the producer itself
                _put(tag_key(t, succs[t][0]), t)
            return None

        start_of[t] = start_side_effects
        return on_scheduled, completion

    def _consume(k, t: TaskId):
        """A get matched an existing tag."""
        if per_dep_tags:
            # one-use tag: disposed by the runtime right after the get.
            # The table counts tags per key — a multigraph (two dependences
            # relating the same task pair, e.g. cholesky_like's panel
            # columns) legitimately puts the same (src, dst) key twice.
            table[k] -= 1
            if table[k] == 0:
                del table[k]
            C.spatial.dec()
            C.inflight_deps.dec()
        else:
            tag_consumers_left[k] -= 1
            if tag_consumers_left[k] == 0:
                C.garbage.inc()  # dead but not destroyable until graph end

    def _put(k, src: TaskId):
        C.spatial.inc()
        C.inflight_deps.inc()
        if per_dep_tags:
            waiters = pending.get(k)
            if waiters:
                # each put satisfies exactly ONE outstanding get (one-use
                # tags pair 1:1 with dependence instances, so a duplicate
                # (src, dst) key must burn one tag per waiting get)
                w = waiters.pop(0)
                if not waiters:
                    del pending[k]
                C.inflight_deps.dec()   # the pending get record
                C.spatial.dec()
                C.spatial.dec()         # the tag, consumed by its getter
                C.inflight_deps.dec()
                waiting_n[w] -= 1
                if waiting_n[w] == 0:
                    sim.make_ready(w, completions[w])
            else:
                table[k] = table.get(k, 0) + 1
        else:
            table[k] = True
            tag_consumers_left[k] = len(succs[src])
            C.inflight_deps.dec()  # tags2: the tag itself resolves on put
            for w in pending.pop(k, []):
                C.inflight_deps.dec()   # the pending get record
                C.spatial.dec()
                tag_consumers_left[k] -= 1
                if tag_consumers_left[k] == 0:
                    C.garbage.inc()
                waiting_n[w] -= 1
                if waiting_n[w] == 0:
                    sim.make_ready(w, completions[w])

    scheduled_hooks: dict[TaskId, Callable] = {}
    completions: dict[TaskId, Callable] = {}
    for t in all_tasks:
        sh, co = make_task(t)
        scheduled_hooks[t] = sh
        completions[t] = co

    # master: schedule all tasks upfront; execution overlaps (O(1) startup)
    ops = []
    for t in all_tasks:
        def op(t=t):
            C.inflight_tasks.inc()
            scheduled_hooks[t]()
        ops.append(op)
    sim.run_master(ops, gate_after_all=False)

    order = _install_start_hook(sim, start_of)
    sim.run()
    name = "tags1" if per_dep_tags else "tags2"
    return RunResult(name, C, order, n_tasks)


def run_tags1(graph, params, workers=4, task_dur=1.0, setup_cost=0.01,
              on_execute=None) -> RunResult:
    return _run_tags(graph, params, True, workers, task_dur, setup_cost, on_execute)


def run_tags2(graph, params, workers=4, task_dur=1.0, setup_cost=0.01,
              on_execute=None) -> RunResult:
    return _run_tags(graph, params, False, workers, task_dur, setup_cost, on_execute)


# --------------------------------------------------------------------------
def run_counted(graph: TiledTaskGraph, params: dict, workers: int = 4,
                task_dur: float = 1.0, setup_cost: float = 0.01,
                on_execute: Hook = None) -> RunResult:
    """Master computes every counter with the §4.3 function: O(n·d) startup."""
    sim = Sim(workers, task_dur, setup_cost)
    C = sim.counters
    all_tasks = list(graph.tasks(params))
    counter: dict[TaskId, int] = {}
    start_of: dict[TaskId, Callable] = {}
    completions: dict[TaskId, Callable] = {}

    def make_task(t: TaskId):
        def start_side_effects():
            C.inflight_tasks.dec()
            C.spatial.dec()        # counter GC'd when the task starts
            C.garbage.dec()
            if on_execute:
                on_execute(t)

        def completion():
            for s in graph.successors(t, params):
                counter[s] -= 1
                if counter[s] == 0:
                    C.inflight_deps.dec()
                    C.garbage.inc()  # dead counter until task start
                    sim.make_ready(s, completions[s])

        start_of[t] = start_side_effects
        completions[t] = completion

    for t in all_tasks:
        make_task(t)

    ops = []
    for t in all_tasks:
        def op(t=t):
            # evaluate predecessor count (cost d), create counter, schedule
            counter[t] = graph.pred_count(t, params)
            C.spatial.inc()
            C.inflight_deps.inc()
            C.inflight_tasks.inc()
        ops.append(op)
    sim.run_master(ops, gate_after_all=True)

    def seed():
        for t in all_tasks:
            if counter[t] == 0:
                C.inflight_deps.dec()
                C.garbage.inc()
                sim.make_ready(t, completions[t])
    sim.at(len(ops) * setup_cost, seed)

    order = _install_start_hook(sim, start_of)
    sim.run()
    return RunResult("counted", C, order, len(all_tasks))


# --------------------------------------------------------------------------
def _run_autodec(graph: TiledTaskGraph, params: dict, with_src: bool,
                 workers: int, task_dur: float, setup_cost: float,
                 on_execute: Hook) -> RunResult:
    sim = Sim(workers, task_dur, setup_cost)
    C = sim.counters
    counter: dict[TaskId, int] = {}
    scheduled: set[TaskId] = set()
    start_of: dict[TaskId, Callable] = {}

    def start_side_effects_for(t: TaskId):
        def f():
            C.inflight_tasks.dec()
            C.spatial.dec()
            C.garbage.dec()
            if on_execute:
                on_execute(t)
        return f

    def completion_for(t: TaskId):
        def f():
            for s in graph.successors(t, params):
                autodec(s)
        return f

    def _get_or_create(t: TaskId) -> None:
        """The atomic init of a counted dependence (autodec & preschedule)."""
        if t not in counter:
            counter[t] = graph.pred_count(t, params)
            C.spatial.inc()
            C.inflight_deps.inc()

    def _fire(t: TaskId) -> None:
        C.inflight_deps.dec()
        C.garbage.inc()          # counter dead until the task starts
        scheduled.add(t)
        C.inflight_tasks.inc()
        start_of[t] = start_side_effects_for(t)
        sim.make_ready(t, completion_for(t))

    def autodec(t: TaskId) -> None:
        _get_or_create(t)
        counter[t] -= 1
        if counter[t] == 0 and t not in scheduled:
            _fire(t)

    def preschedule(t: TaskId) -> None:
        _get_or_create(t)
        if with_src is False:
            pass  # task known to master anyway; scheduling happens on fire
        if counter[t] == 0 and t not in scheduled:
            _fire(t)

    if with_src:
        seeds = list(graph.roots(params))   # §4.3 static root set
        n_tasks = graph.num_tasks(params)
    else:
        seeds = list(graph.tasks(params))   # preschedule everything
        n_tasks = len(seeds)

    ops = [lambda t=t: preschedule(t) for t in seeds]
    sim.run_master(ops, gate_after_all=False)

    order = _install_start_hook(sim, start_of)
    sim.run()
    name = "autodec" if with_src else "autodec_nosrc"
    return RunResult(name, C, order, n_tasks)


def run_autodec(graph, params, workers=4, task_dur=1.0, setup_cost=0.01,
                on_execute=None) -> RunResult:
    return _run_autodec(graph, params, True, workers, task_dur, setup_cost, on_execute)


def run_autodec_nosrc(graph, params, workers=4, task_dur=1.0, setup_cost=0.01,
                      on_execute=None) -> RunResult:
    return _run_autodec(graph, params, False, workers, task_dur, setup_cost, on_execute)


MODELS: dict[str, Callable] = {
    "prescribed": run_prescribed,
    "tags1": run_tags1,
    "tags2": run_tags2,
    "counted": run_counted,
    "autodec": run_autodec,
    "autodec_nosrc": run_autodec_nosrc,
}


def run_model(name: str, graph: TiledTaskGraph, params: dict, **kw) -> RunResult:
    return MODELS[name](graph, params, **kw)


def validate_order(graph: TiledTaskGraph, params: dict, result: RunResult,
                   task_dur: float = 1.0) -> None:
    """Every task ran exactly once; no successor started before its
    predecessor completed."""
    start = {}
    for t, at in result.order:
        assert t not in start, f"task {t} executed twice"
        start[t] = at
    all_tasks = set(graph.tasks(params))
    assert set(start) == all_tasks, (
        f"executed {len(start)} of {len(all_tasks)} tasks; "
        f"missing e.g. {list(all_tasks - set(start))[:3]}")
    for t in all_tasks:
        for s in graph.successors(t, params):
            assert start[s] >= start[t] + task_dur, f"dependence violated: {t} -> {s}"
