"""Instrumented event-driven execution substrate.

Two execution backends:

* :class:`Sim` — a deterministic discrete-event simulator with ``k`` worker
  slots and a dedicated master lane.  All of the paper's §2 overhead metrics
  are tracked *exactly* (they are object-lifetime counts, machine
  independent), and the makespan gives the wall-time trends of §5.2 without
  noise from the host (this container has a single core).

* :class:`ThreadedAutodec` (in ``threaded.py``) — a real thread-pool runtime
  for the autodec model, proving the atomic get-or-create under true
  concurrency; it is also what the training runtime layer uses for async
  orchestration (prefetch / checkpoint / straggler backups).

Overhead gauges (paper Table 2):
  ``startup``        sequential master ops before the first task can start
  ``spatial``        live synchronization objects (edges / tags / counters)
  ``inflight_tasks`` tasks known to the scheduler but not yet ready/running
  ``inflight_deps``  unresolved dependence objects
  ``garbage``        objects whose last use has passed but not yet destroyed
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


class Gauge:
    """Current value + high-water mark."""

    __slots__ = ("cur", "peak", "total")

    def __init__(self) -> None:
        self.cur = 0
        self.peak = 0
        self.total = 0

    def inc(self, k: int = 1) -> None:
        self.cur += k
        self.total += k
        if self.cur > self.peak:
            self.peak = self.cur

    def dec(self, k: int = 1) -> None:
        self.cur -= k


@dataclass
class Counters:
    """The five Table-2 overheads + makespan, measured not asserted."""
    startup_ops: int = 0
    spatial: Gauge = field(default_factory=Gauge)
    inflight_tasks: Gauge = field(default_factory=Gauge)
    inflight_deps: Gauge = field(default_factory=Gauge)
    garbage: Gauge = field(default_factory=Gauge)
    makespan: float = 0.0
    master_ops: int = 0

    def summary(self) -> dict:
        return {
            "startup_ops": self.startup_ops,
            "spatial_peak": self.spatial.peak,
            "inflight_tasks_peak": self.inflight_tasks.peak,
            "inflight_deps_peak": self.inflight_deps.peak,
            "garbage_peak": self.garbage.peak,
            "sync_objects_total": self.spatial.total,
            "makespan": self.makespan,
            "master_ops": self.master_ops,
        }


class Sim:
    """Discrete-event simulator: ``workers`` task slots + 1 master lane.

    The master runs a generator of setup *ops*; each op costs ``setup_cost``
    time on the master lane.  Tasks cost ``task_dur`` and occupy a worker.
    Models dispatch ready tasks via :meth:`make_ready`; whether tasks may
    start before the master finishes is the model's choice (``gate``).
    """

    def __init__(self, workers: int = 4, task_dur: float = 1.0,
                 setup_cost: float = 0.01,
                 on_task_error: Optional[Callable] = None,
                 on_start: Optional[Callable] = None):
        self.workers = workers
        self.task_dur = task_dur
        self.setup_cost = setup_cost
        # Start hook: called with the task key at dispatch time, after the
        # task is recorded in exec_order and before its completion is
        # scheduled.  The sync models hang their GC-at-start side effects
        # here (syncmodels.py) — it is part of the dispatch loop proper, so
        # model instrumentation can never drift from the real exactly-once
        # guard / worker accounting the way a monkey-patched clone of
        # _dispatch would.  Settable after construction.
        self.on_start = on_start
        # Robustness hook: with on_task_error set, a run_fn exception is
        # caught at completion time — recorded in task_errors and reported
        # to the callback — instead of unwinding through run() and leaving
        # the event heap mid-dispatch (a wedged simulator).  The failed
        # task's worker slot is freed either way.
        self.on_task_error = on_task_error
        self.task_errors: list = []
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self.free = workers
        # FIFO of (task_key, run_fn); deque so dispatch is O(1) per task
        # (list.pop(0) made the ready queue O(n^2) at scale).
        self.ready: deque = deque()
        self.gate_open = True
        self.counters = Counters()
        self._started_any = False
        self.exec_order: list = []
        self.running = 0
        # Exactly-once guard: every key ever enqueued.  A task made ready
        # twice would double-start and leak counters (the class of bug the
        # PR-4 threaded stress test caught in ThreadedAutodec); the Sim
        # layer rejects it at enqueue time rather than mis-counting later.
        self._enqueued: set = set()

    # ---------------------------------------------------------------- events
    def at(self, dt: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self.now + dt, next(self._seq), fn))

    def run(self) -> None:
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.counters.makespan = self.now

    # ---------------------------------------------------------------- master
    def run_master(self, ops, gate_after_all: bool) -> None:
        """Schedule master setup ops; optionally gate task execution on them.

        ``ops`` is an iterable of callables.  With ``gate_after_all`` the gate
        opens only when every op has run (prescribed / counted models); the
        number of ops before the gate opens is the sequential start-up
        overhead.  Without it the gate is open from the start (tags /
        autodec): setup overlaps execution.
        """
        ops = list(ops)
        self.gate_open = not gate_after_all
        n = len(ops)
        self.counters.master_ops += n
        self.counters.startup_ops += n if gate_after_all else min(1, n)

        def step(i: int) -> None:
            if i < n:
                ops[i]()
                self.at(self.setup_cost, lambda: step(i + 1))
            else:
                if gate_after_all:
                    self.gate_open = True
                    self._dispatch()

        self.at(0.0, lambda: step(0))

    # ---------------------------------------------------------------- tasks
    def _claim(self, key) -> None:
        """Record ``key`` as enqueued; reject a second make-ready of it."""
        if key in self._enqueued:
            raise ValueError(
                f"task {key!r} was already made ready: a duplicate enqueue "
                f"would double-start it and corrupt the overhead counters")
        self._enqueued.add(key)

    def make_ready(self, key, run_fn: Callable[[], None]) -> None:
        self._claim(key)
        self.ready.append((key, run_fn))
        self._dispatch()

    def make_ready_batch(self, items) -> None:
        """Enqueue a whole wavefront level in one call.

        ``items`` is an iterable of ``(key, run_fn)`` pairs; the queue is
        extended en bloc and dispatched once — level-sized batches from the
        wavefront scheduler don't pay a dispatch attempt per task.  Each
        key must be new to this Sim (exactly-once; ``ValueError`` on a
        duplicate, within the batch or against any earlier enqueue).
        """
        claim = self._claim
        ready = self.ready
        for key, run_fn in items:
            claim(key)
            ready.append((key, run_fn))
        self._dispatch()

    def make_ready_ids(self, ids, run_fn: Callable[[], None]) -> None:
        """Enqueue a level of integer task ids sharing one completion fn.

        Fed straight from merged index arrays (sharded materialization /
        :class:`IndexedSchedule` levels): keys are plain ints and every
        task of the level shares ``run_fn``, so driving a million-task
        schedule allocates no per-task closures or label tuples.  Ids are
        validated exactly-once like every other enqueue path
        (``ValueError`` on a duplicate).
        """
        claim = self._claim
        ready = self.ready
        for i in ids:
            key = int(i)
            claim(key)
            ready.append((key, run_fn))
        self._dispatch()

    def _dispatch(self) -> None:
        if not self.gate_open:
            return
        while self.free > 0 and self.ready:
            key, run_fn = self.ready.popleft()
            self.free -= 1
            self.running += 1
            self.exec_order.append(key)
            self._started_any = True
            if self.on_start is not None:
                self.on_start(key)

            def complete(key=key, run_fn=run_fn) -> None:
                try:
                    run_fn()
                except BaseException as e:  # noqa: BLE001 — see __init__
                    if self.on_task_error is None:
                        raise
                    self.task_errors.append((key, e))
                    self.on_task_error(key, e)
                finally:
                    self.free += 1
                    self.running -= 1
                self._dispatch()

            self.at(self.task_dur, complete)

    # ------------------------------------------------------------- progress
    def progress(self) -> tuple[int, int]:
        """Monotone ``(started, finished)`` counters for a stall watchdog
        (:class:`~repro.core.edt.recovery.Watchdog`)."""
        started = len(self.exec_order)
        return started, started - self.running
