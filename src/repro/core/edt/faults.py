"""Deterministic fault injection for the EDT pipeline.

The counted-sync model lives and dies by its invariants — every counter
drained exactly once, every sync object collected — and those invariants
only mean something if the pipeline survives their violation *visibly*:
a dead pool worker must not corrupt a merged graph, a dropped decrement
must surface as a diagnosable stall instead of an infinite hang, and a
task-body exception must poison exactly its dependent cone.

This module is the *injection* half of that story (``recovery.py`` is the
response half).  A :class:`FaultPlan` is a seeded, picklable description of
which faults fire where:

=====================  =====================================================
kind                   meaning / injection site
=====================  =====================================================
``WORKER_CRASH``       a shard job dies mid-round — raised in the worker
                       (``hard=True`` kills the whole process with
                       ``os._exit``, breaking the pool)
``WORKER_HANG``        a shard job sleeps past the round timeout
``SHM_ATTACH_FAIL``    a worker fails to attach its shared-memory slot
``TASK_BODY_ERROR``    a task body raises at task ``t`` (threaded / Sim)
``DROPPED_DECREMENT``  one predecessor signal of task ``t`` never arrives
                       (threaded successors / device counter init)
``RANK_CRASH``         a distributed rank dies mid-run (``index`` = rank;
                       ``hard=True`` kills the rank process)
``MESSAGE_LOSS``       one cross-rank decrement batch is dropped in flight
                       (``round`` = source rank, ``index`` = destination)
=====================  =====================================================

Shard faults address a pool round (0 = counts, 1 = tiles, 2 = edges) and a
job index within it; ``times`` bounds how many successive *attempts* fail,
so ``times <= RetryPolicy.max_retries`` makes a fault recoverable by
construction.  The plan records every fire in ``fired`` (driver side), so
tests can assert a fault actually triggered rather than silently missing
its target.

Injection is explicit and zero-cost when absent: every hook site takes
``Optional[FaultPlan]`` (or a per-job ``Optional[Fault]``) and the
fault-free fast paths are unchanged.
"""

from __future__ import annotations

import os
import random
import time
from dataclasses import dataclass, field
from typing import Optional

WORKER_CRASH = "worker_crash"
WORKER_HANG = "worker_hang"
SHM_ATTACH_FAIL = "shm_attach_fail"
TASK_BODY_ERROR = "task_body_error"
DROPPED_DECREMENT = "dropped_decrement"
RANK_CRASH = "rank_crash"
MESSAGE_LOSS = "message_loss"

SHARD_KINDS = (WORKER_CRASH, WORKER_HANG, SHM_ATTACH_FAIL)
DIST_KINDS = (RANK_CRASH, MESSAGE_LOSS)
KINDS = SHARD_KINDS + (TASK_BODY_ERROR, DROPPED_DECREMENT) + DIST_KINDS


class InjectedWorkerCrash(RuntimeError):
    """A shard worker died mid-round (soft injection)."""


class InjectedAttachFailure(OSError):
    """A shard worker could not attach its shared-memory segment."""


class InjectedTaskError(RuntimeError):
    """A task body raised (the injected fault of ``TASK_BODY_ERROR``)."""

    def __init__(self, task):
        super().__init__(f"injected task-body fault at task {task!r}")
        self.task = task


class InjectedRankCrash(RuntimeError):
    """A distributed rank died mid-run (soft injection of ``RANK_CRASH``)."""

    def __init__(self, rank: int, attempt: int):
        super().__init__(
            f"injected rank crash (rank {rank}, attempt {attempt})")
        self.rank = rank


@dataclass(frozen=True)
class Fault:
    """One injected fault — picklable, addressed by site.

    ``round``/``index`` address shard faults (pool round × job index);
    ``task`` addresses task-level faults (a TaskId or a global task id).
    ``times`` is the number of successive attempts that fail: a retrying
    driver recovers iff ``times <= max_retries``.  ``delay`` is the hang
    duration; ``hard`` upgrades a crash to ``os._exit`` (kills the worker
    process, breaking every in-flight job of the pool).
    """

    kind: str
    round: int = -1
    index: int = 0
    task: object = None
    times: int = 1
    delay: float = 0.5
    hard: bool = False


def maybe_inject(fault: Optional[Fault], attempt: int) -> None:
    """Fire ``fault`` if this attempt is within its ``times`` budget.

    Runs *inside* the worker (shard jobs) or the task body wrapper.  A
    crash raises (or kills the process when ``hard``), a hang sleeps past
    the driver's round timeout, an attach failure raises ``OSError`` — the
    driver treats all three identically: the shard failed, retry it.
    """
    if fault is None or attempt >= fault.times:
        return
    if fault.kind == WORKER_CRASH:
        if fault.hard:
            os._exit(1)
        raise InjectedWorkerCrash(
            f"injected worker crash (round {fault.round}, job {fault.index}, "
            f"attempt {attempt})")
    if fault.kind == WORKER_HANG:
        time.sleep(fault.delay)
    elif fault.kind == SHM_ATTACH_FAIL:
        raise InjectedAttachFailure(
            f"injected shm attach failure (round {fault.round}, "
            f"job {fault.index}, attempt {attempt})")


@dataclass
class FaultPlan:
    """A seeded set of faults plus a driver-side log of what fired.

    Accessors are cheap enough to sit on hot paths guarded by
    ``plan is not None``.  ``fired`` is appended to by the recovery layer
    (one entry per observed failure/injection), so a test can assert both
    that recovery succeeded *and* that the fault it planted actually went
    off.
    """

    faults: tuple = ()
    seed: Optional[int] = None
    fired: list = field(default_factory=list)

    def __post_init__(self):
        self.faults = tuple(self.faults)

    # ------------------------------------------------------------ accessors
    def shard_fault(self, round_no: int, index: int) -> Optional[Fault]:
        for f in self.faults:
            if f.kind in SHARD_KINDS and f.round == round_no and f.index == index:
                return f
        return None

    def body_fault(self, task) -> Optional[Fault]:
        for f in self.faults:
            if f.kind == TASK_BODY_ERROR and f.task == task:
                return f
        return None

    def hang_fault(self, task) -> Optional[Fault]:
        for f in self.faults:
            if f.kind == WORKER_HANG and f.task == task:
                return f
        return None

    def dropped_tasks(self) -> list:
        return [f.task for f in self.faults if f.kind == DROPPED_DECREMENT]

    def rank_fault(self, rank: int) -> Optional[Fault]:
        """The ``RANK_CRASH`` fault addressed to ``rank`` (``index``), if any."""
        for f in self.faults:
            if f.kind == RANK_CRASH and f.index == rank:
                return f
        return None

    def message_fault(self, src_rank: int, dst_rank: int) -> Optional[Fault]:
        """The ``MESSAGE_LOSS`` fault on the ``src -> dst`` channel
        (``round`` = source rank, ``index`` = destination rank), if any."""
        for f in self.faults:
            if (f.kind == MESSAGE_LOSS and f.round == src_rank
                    and f.index == dst_rank):
                return f
        return None

    def shard_kinds(self) -> list:
        return [f for f in self.faults if f.kind in SHARD_KINDS]

    def dist_kinds(self) -> list:
        return [f for f in self.faults if f.kind in DIST_KINDS]

    def record(self, kind: str, where, attempt: int, error=None) -> None:
        self.fired.append((kind, where, attempt, repr(error) if error else None))

    # ------------------------------------------------------- recoverability
    def recoverable(self, max_retries: int) -> bool:
        """Whether a retrying run must end byte-identical.

        Shard faults and distributed faults (rank crash, message loss)
        recover iff every one exhausts within the retry budget — shard
        blocks and whole distributed attempts are both pure functions of
        their inputs, so a retried run reproduces the fault-free bytes.
        Task-level faults are never "recovered" — they quarantine or stall
        by design — so a plan containing them is judged on the retryable
        kinds only.
        """
        return all(f.times <= max_retries
                   for f in self.shard_kinds() + self.dist_kinds())

    # ------------------------------------------------------------- factory
    @classmethod
    def random(cls, seed: int, n_jobs: int = 4, tasks=(),
               kinds=SHARD_KINDS, max_times: int = 3,
               n_faults: int = 1) -> "FaultPlan":
        """A seeded random plan — the fuzzing entry point.

        ``n_jobs`` bounds the shard job index, ``tasks`` supplies the task
        universe for task-level kinds, ``max_times`` bounds the attempt
        budget (so recoverability is decided by the caller's retry policy,
        not the generator).
        """
        rng = random.Random(seed)
        faults = []
        for _ in range(n_faults):
            kind = rng.choice(tuple(kinds))
            if kind in SHARD_KINDS:
                faults.append(Fault(
                    kind=kind,
                    round=rng.randrange(3),
                    index=rng.randrange(max(1, n_jobs)),
                    times=rng.randint(1, max_times),
                    delay=0.3,
                    hard=(kind == WORKER_CRASH and rng.random() < 0.25)))
            else:
                if not len(tasks):
                    continue
                faults.append(Fault(
                    kind=kind, task=tasks[rng.randrange(len(tasks))]))
        return cls(faults=tuple(faults), seed=seed)
