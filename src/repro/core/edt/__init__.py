"""Event-driven task graphs: construction (§3/§4), sync models (§2), execution.

``__all__`` below is the stable public surface.  Execution knobs go
through :class:`ExecutionConfig`/:class:`Session` (``docs/backends.md``,
migration section); the per-call ``shards=``/``parallel=``/``pool=``/
``faults=``/``recovery=`` kwargs are deprecated shims.
"""
from .atlas import (ATLAS_COUNTERS, AtlasWorkload, Instance, WORKLOADS,
                    atlas_crossover, atlas_sweep, build_instances, fit_class,
                    fit_rows, growth_rows, measure, reference_curves)
from .cache import GraphCache, graph_cache_info
from .config import CachePolicy, ExecutionConfig, Session
from .device import (DeviceCounters, DeviceExecutor, DeviceGraph, DeviceRun,
                     DeviceSchedule, make_pallas_step, make_xla_step,
                     pack_graph, pack_schedule)
from .distributed import (DistributedRun, Mailbox, MsgBatch, RankEngine,
                          RankFailureError, RankSlice, RankStats,
                          partition_graph, plan_ranks, run_distributed)
from .executor import Counters, Gauge, Sim
from .faults import (DROPPED_DECREMENT, MESSAGE_LOSS, RANK_CRASH,
                     SHM_ATTACH_FAIL, TASK_BODY_ERROR, WORKER_CRASH,
                     WORKER_HANG, Fault, FaultPlan, InjectedRankCrash,
                     InjectedTaskError)
from .fused import (FusedExecutor, FusedRun, graph_tile, host_execute,
                    pack_origins)
from .recovery import (FailureReport, ResilientRun, RetryPolicy,
                       ScheduleValidationError, ShardRecoveryError,
                       StallError, StallReport, TaskGroupError, Watchdog,
                       poisoned_cone, simulate_indexed_resilient)
from .service import ScheduleService
from .shard import ShardPlan, ShardSpec, plan_shards, scan_sharded
from .syncmodels import (MODELS, RunResult, run_autodec, run_autodec_nosrc,
                         run_counted, run_model, run_prescribed, run_tags1,
                         run_tags2, validate_order)
from .taskgraph import (Dependence, IndexedGraph, MaterializedGraph,
                        PolyhedralProgram, Statement, TaskId, TiledTaskGraph)
from .threaded import (ThreadedAutodec, ThreadedRunResult, run_graph_threaded,
                       run_graph_threaded_resilient)
from .wavefront import (IndexedSchedule, WavefrontSchedule, levels_from_array,
                        schedule_from_graph, simulate_indexed,
                        simulate_schedule, synthesize, synthesize_indexed)

__all__ = [
    "PolyhedralProgram", "Statement", "Dependence", "TiledTaskGraph",
    "MaterializedGraph", "IndexedGraph", "TaskId",
    "ExecutionConfig", "CachePolicy", "Session",
    "GraphCache", "graph_cache_info", "ScheduleService",
    "ShardSpec", "ShardPlan", "plan_shards", "scan_sharded",
    "DeviceExecutor", "DeviceRun", "DeviceCounters", "DeviceGraph",
    "DeviceSchedule", "pack_graph", "pack_schedule",
    "make_xla_step", "make_pallas_step",
    "run_distributed", "DistributedRun", "RankEngine", "RankSlice",
    "RankStats", "RankFailureError", "Mailbox", "MsgBatch",
    "plan_ranks", "partition_graph",
    "FusedExecutor", "FusedRun", "pack_origins", "host_execute",
    "graph_tile",
    "Sim", "Counters", "Gauge",
    "AtlasWorkload", "Instance", "WORKLOADS", "ATLAS_COUNTERS",
    "atlas_sweep", "atlas_crossover", "build_instances", "measure",
    "reference_curves", "fit_class", "fit_rows", "growth_rows",
    "MODELS", "run_model", "RunResult", "validate_order",
    "run_prescribed", "run_tags1", "run_tags2", "run_counted",
    "run_autodec", "run_autodec_nosrc",
    "ThreadedAutodec", "run_graph_threaded", "run_graph_threaded_resilient",
    "ThreadedRunResult",
    "Fault", "FaultPlan", "InjectedTaskError", "InjectedRankCrash",
    "WORKER_CRASH", "WORKER_HANG", "SHM_ATTACH_FAIL", "TASK_BODY_ERROR",
    "DROPPED_DECREMENT", "RANK_CRASH", "MESSAGE_LOSS",
    "RetryPolicy", "FailureReport", "StallReport", "StallError",
    "ShardRecoveryError", "TaskGroupError", "ScheduleValidationError",
    "Watchdog", "poisoned_cone", "simulate_indexed_resilient", "ResilientRun",
    "WavefrontSchedule", "synthesize", "simulate_schedule",
    "IndexedSchedule", "synthesize_indexed", "simulate_indexed",
    "levels_from_array", "schedule_from_graph",
]
