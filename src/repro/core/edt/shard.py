"""Sharded parallel materialization — scale-out task-graph generation.

PR 1-2 made :meth:`TiledTaskGraph.materialize` cheap and embarrassingly
parallel per (statement × dependence): every statement's tile domain and
every dependence's joint Δ_T polyhedron is one independent vectorized scan.
This module fans those scans out across processes for million-task graphs.

The unit of work is a :class:`ShardSpec`: one outer-dimension block of one
scan unit (a statement's tile domain or a dependence's joint polyhedron).
Because lexicographic scans emit the outermost dim in ascending order, a
scan restricted to ``lo <= d0 <= hi`` produces *exactly* the contiguous row
range of the full scan whose first coordinate lies in the block — so
per-shard index arrays laid out in block order are **byte-identical** to
the single-process scan.  The restriction itself is expressed with two
extra scan parameters (:func:`~repro.core.poly.scanning.shard_polyhedron`),
so all shards of a unit share one canonical polyhedron and the per-process
compiled-scan cache stays warm: each worker compiles each unit once, no
matter how many blocks it receives.

Three design points make the merge *streaming* — per-shard results never
exist as Python objects, only as slices of the final arrays:

1. **Exact pre-counting, in parallel.**  A first pool round evaluates each
   block's row count with the generated vectorized counters (tile-level
   self pairs are subtracted via the diagonal sub-polyhedron), which fixes
   every block's destination offset before any scan runs — and warms each
   worker's nest cache for the scan rounds.
2. **Shared-memory placement.**  Per-unit result segments are allocated at
   final size in ``/dev/shm``; workers write their block's rows straight
   into ``[offset, offset+count)``.  Nothing is pickled back and nothing
   is concatenated — the "merge" is the address layout.  (A pickle
   transport remains as an automatic fallback when shared memory is
   unavailable.)
3. **In-worker index mapping.**  Edge blocks ship with the two statement
   maps (:class:`StmtMap`) built from the merged tile phase; workers drop
   tile-level self pairs and map endpoints to **global task ids** (dense
   boxes: the mixed-radix key *is* the index; other shapes searchsorted
   against the statement's key table, itself published as a read-only
   shared segment).  The driver never touches per-edge data again — it
   only bincounts in-degrees from the final columns.

Entry points:

* :func:`scan_sharded` — run a plan on a process pool, return the merged
  :class:`ShardedScans`.
* ``TiledTaskGraph.materialize(params, config=ExecutionConfig(shards=n))``
  / ``index_graph(...)`` / ``roots(...)`` — the graph-level APIs thread
  through here whenever the config resolves to >1 shard (the old
  per-call ``shards=n`` kwarg still works via the deprecation shim).
* :func:`plan_shards` — the deterministic partition (inspectable/testable
  without a pool).
"""
from __future__ import annotations

import os
import weakref
from concurrent.futures import Executor, ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..poly.scanning import LoopNest, shard_polyhedron
from .faults import FaultPlan, maybe_inject
from .recovery import RetryPolicy, run_round

TILES = "tiles"
EDGES = "edges"

# Blocks per unit beyond the shard count: outer-dim blocks of equal extent
# carry unequal point counts (triangular domains), so oversubscription keeps
# the pool busy while the deterministic merge order is preserved.
OVERSUBSCRIBE = 4

# With the pickle transport (no shared memory), inline a non-dense
# statement's sorted key table into edge jobs only below this size; above
# it, raw coordinate rows come back and the driver maps them.
KEYS_SHIP_LIMIT = 200_000


@dataclass(frozen=True)
class ShardSpec:
    """One outer-dim block of one scan unit — picklable, deterministic."""
    kind: str               # TILES (statement) | EDGES (tiled-dep index)
    key: object             # statement name | index into graph.tiled_deps
    poly: object            # __slo/__shi-extended canonical Polyhedron
    pv: tuple               # graph parameter values (block range excluded)
    lo: int                 # outer-dim block [lo, hi], inclusive
    hi: int
    seq: int                # merge position within the (kind, key) unit


@dataclass(frozen=True)
class StmtMap:
    """Coordinate -> global-task-id map for one statement (picklable).

    ``dense`` means the tile block fills its bounding box, so the
    mixed-radix key *is* the local index.  Otherwise the sorted key table
    lives either inline (``keys``) or in a read-only shared segment
    (``keys_shm = (name, n)``) that workers attach on use.  When neither
    is available the map is unusable and edge workers return raw rows.
    """
    mins: "np.ndarray"      # (d,) per-dim minima
    strides: "np.ndarray"   # (d,) mixed-radix strides
    dense: bool
    base: int               # global id of the statement's first task
    n: int                  # task count
    keys: Optional["np.ndarray"] = None
    keys_shm: Optional[tuple] = None

    @property
    def usable(self) -> bool:
        return self.dense or self.keys is not None or self.keys_shm is not None

    def map_global(self, coords: "np.ndarray") -> "np.ndarray":
        k = (coords - self.mins) @ self.strides
        if self.dense:
            return k + self.base
        if self.keys is not None:
            return np.searchsorted(self.keys, k) + self.base
        name, n = self.keys_shm
        seg, shm = _open_segment(name, (n,))
        try:
            out = np.searchsorted(seg, k)
        finally:
            del seg
            if shm is not None:
                shm.close()
        return out + self.base


@dataclass(frozen=True)
class _Slot:
    """Destination of one block: segment name/shape + row offset + count."""
    shm: Optional[str]      # SharedMemory name; None -> pickle the result
    shape: tuple            # full segment shape
    off: int
    count: int              # exact rows this block must produce


@dataclass(frozen=True)
class _CountJob:
    spec: ShardSpec
    diag_poly: Optional[object]   # sharded Δ_T ∩ {T_s = T_t}, or None


@dataclass(frozen=True)
class _TileJob:
    spec: ShardSpec
    slot: _Slot


@dataclass(frozen=True)
class _EdgeJob:
    """An EDGES block plus everything needed to map endpoints in-worker."""
    spec: ShardSpec
    slot: _Slot
    ns: int                 # source tile dims (split column of the scan)
    self_dep: bool          # drop (T, T) rows
    smap: Optional[StmtMap]  # None -> raw coordinate rows (driver maps)
    tmap: Optional[StmtMap]


@dataclass
class ShardPlan:
    """The partitioned work list plus units resolved in-driver."""
    tile_specs: list[ShardSpec] = field(default_factory=list)
    edge_specs: list[ShardSpec] = field(default_factory=list)
    local: dict = field(default_factory=dict)   # (kind, key) -> scanned array

    @property
    def n_shards(self) -> int:
        return len(self.tile_specs) + len(self.edge_specs)


@dataclass
class ShardedScans:
    """Merged scan products, ready for the index/materialize consumers.

    ``tiles``: per-statement ``(N, d)`` coordinate blocks — byte-identical
    to ``tile_nests[name].iterate_array``.  Each dependence lands in
    exactly one of ``edges_idx`` (worker-mapped ``(src_ids, tgt_ids)``
    global index columns, self pairs already dropped) or ``edges_raw``
    (joint coordinate rows, self pairs already dropped, mapped by the
    driver like the single-process path).  Arrays may be backed by
    unlinked shared-memory segments; each owns its mapping
    (:class:`_ShmArray`), so they outlive this object safely.
    """
    tiles: dict = field(default_factory=dict)
    edges_idx: dict = field(default_factory=dict)
    edges_raw: dict = field(default_factory=dict)


# ---------------------------------------------------------------- workers
# Per-process LoopNest cache: every block of a unit reuses the nest (and the
# module-level compiled-scan cache keyed by the canonical polyhedron), so a
# worker pays FM projection + codegen once per unit, not once per block.
_NESTS: dict = {}


def _nest_for(poly) -> LoopNest:
    key = (poly.dim_names, poly.param_names, poly.ineqs, poly.eqs)
    nest = _NESTS.get(key)
    if nest is None:
        _NESTS[key] = nest = LoopNest(poly)
    return nest


def _block_scan(spec: ShardSpec) -> "np.ndarray":
    return _nest_for(spec.poly).iterate_array(
        tuple(spec.pv) + (spec.lo, spec.hi))


def _open_segment(name: str, shape):
    """Attach a driver-owned segment, preferring a direct ``np.memmap`` of
    the POSIX shm file — the worker never constructs a ``SharedMemory``
    object, so no Python version's attach-side resource tracking can
    interfere (falls back to a plain attach where /dev/shm has no file)."""
    path = f"/dev/shm/{name}"
    if os.path.exists(path):
        return np.memmap(path, dtype=np.int64, mode="r+", shape=shape), None
    from multiprocessing import shared_memory
    shm = shared_memory.SharedMemory(name=name)
    return np.ndarray(shape, dtype=np.int64, buffer=shm.buf), shm


def _deposit(slot: _Slot, rows) -> int:
    """Write a block's rows into its segment slice."""
    if isinstance(rows, tuple):
        n = rows[0].shape[0]
    else:
        n = rows.shape[0]
    assert n == slot.count, (
        f"block produced {n} rows, planner counted {slot.count}")
    seg, shm = _open_segment(slot.shm, slot.shape)
    try:
        if isinstance(rows, tuple):
            seg[0, slot.off:slot.off + n] = rows[0]
            seg[1, slot.off:slot.off + n] = rows[1]
        else:
            seg[slot.off:slot.off + n] = rows
    finally:
        del seg
        if shm is not None:
            shm.close()
    return n


def _count_shard(job: _CountJob) -> int:
    """Worker: exact post-filter row count of one block, no enumeration.

    Warms this process's nest cache for the scan round that follows.
    """
    pv = tuple(job.spec.pv) + (job.spec.lo, job.spec.hi)
    n = _nest_for(job.spec.poly).count_vectorized(pv)
    if job.diag_poly is not None:
        n -= _nest_for(job.diag_poly).count_vectorized(pv)
    return n


def _scan_tile_shard(job: _TileJob):
    """Worker: scan one tile-domain block into its slot."""
    arr = _block_scan(job.spec)
    if job.slot.shm is None:
        return job.spec.key, job.spec.seq, arr
    return job.spec.key, job.spec.seq, _deposit(job.slot, arr)


def _scan_edge_shard(job: _EdgeJob):
    """Worker: scan one dependence block; filter self pairs; map endpoints
    to global ids when the statement maps were shipped."""
    arr = _block_scan(job.spec)
    ns = job.ns
    if job.self_dep and arr.shape[0]:
        arr = arr[(arr[:, :ns] != arr[:, ns:]).any(axis=1)]
    if job.smap is None or job.tmap is None:
        rows = arr
    else:
        rows = (job.smap.map_global(arr[:, :ns]),
                job.tmap.map_global(arr[:, ns:]))
    if job.slot.shm is None:
        return job.spec.key, job.spec.seq, rows
    return job.spec.key, job.spec.seq, _deposit(job.slot, rows)


# Payload entries: every pool round ships ``(job, fault, attempt)`` tuples
# so an injected fault (crash / hang / attach failure) fires *inside* the
# worker before the scan runs — the driver's recovery loop sees exactly
# what a real worker death looks like.  Fault-free runs pass fault=None and
# pay one tuple unpack.
def _job_count(payload) -> int:
    job, fault, attempt = payload
    maybe_inject(fault, attempt)
    return _count_shard(job)


def _job_tile(payload):
    job, fault, attempt = payload
    maybe_inject(fault, attempt)
    return _scan_tile_shard(job)


def _job_edge(payload):
    job, fault, attempt = payload
    maybe_inject(fault, attempt)
    return _scan_edge_shard(job)


# ----------------------------------------------------------------- planning
def _unit_plan(plan: ShardPlan, kind: str, key, nest: LoopNest,
               pv: list, shards: int, oversubscribe: int) -> None:
    """Partition one scan unit into outer-dim blocks (or resolve locally)."""
    bounds = nest.outer_bounds(pv) if nest.ndim else None
    if bounds is None:
        # 0-dim, infeasible, or unbounded outer dim: scan in the driver —
        # these are exactly the cases a block partition cannot help with
        # (and iterate_array raises the same error sharded or not).
        plan.local[(kind, key)] = nest.iterate_array(pv)
        return
    lb, ub = bounds
    extent = ub - lb + 1
    if extent <= 0:
        plan.local[(kind, key)] = np.empty((0, nest.ndim), dtype=np.int64)
        return
    nblocks = min(extent, max(1, shards * oversubscribe))
    spoly = shard_polyhedron(nest.poly)
    q, r = divmod(extent, nblocks)
    specs = plan.tile_specs if kind == TILES else plan.edge_specs
    lo = lb
    for seq in range(nblocks):
        hi = lo + q - 1 + (1 if seq < r else 0)
        specs.append(ShardSpec(kind=kind, key=key, poly=spoly,
                               pv=tuple(pv), lo=lo, hi=hi, seq=seq))
        lo = hi + 1
    assert lo == ub + 1


def plan_shards(graph, params: dict, shards: int,
                oversubscribe: int = OVERSUBSCRIBE) -> ShardPlan:
    """Deterministic (statement × dependence × outer-block) work list.

    Block boundaries depend only on the graph, the params, and the shard
    count — never on pool scheduling — so the merged result is reproducible
    and byte-identical to the single-process scan by construction.
    """
    pv = graph._pv(params)
    plan = ShardPlan()
    for name in graph.program.statements:
        _unit_plan(plan, TILES, name, graph.tile_nests[name], pv,
                   shards, oversubscribe)
    for i, td in enumerate(graph.tiled_deps):
        _unit_plan(plan, EDGES, i, graph._joint_nest(td), pv,
                   shards, oversubscribe)
    return plan


# ------------------------------------------------------------ driver side
def _diag_shard_poly(graph, td_idx: int):
    """Sharded Δ_T ∩ {T_src = T_tgt} — counts a block's self pairs.

    Cached per graph: the polyhedron depends only on the dependence.
    """
    cache = graph._shard_nests
    key = ("diag", td_idx)
    got = cache.get(key)
    if got is None:
        td = graph.tiled_deps[td_idx]
        poly = graph._joint_nest(td).poly
        ns = graph.tilings[td.dep.src].ndim
        for i in range(ns):
            row = [0] * (poly.ndim + poly.nparam + 1)
            row[i], row[ns + i] = 1, -1
            poly = poly.add_eq(row)
        cache[key] = got = shard_polyhedron(poly.canonical())
    return got


class _ShmArray(np.ndarray):
    """An ndarray that owns its shared-memory segment.

    numpy does not pin the exporting memoryview, so a plain ndarray over
    ``shm.buf`` dangles once the ``SharedMemory`` object is collected (its
    ``__del__`` closes the mapping).  The segment rides along on the array
    instead: any view derived from it keeps the base array — and therefore
    the mapping — alive, with no other lifecycle management.
    """
    _shm = None

    def __array_finalize__(self, obj):
        if obj is not None and self._shm is None:
            self._shm = getattr(obj, "_shm", None)


def _release_segments(segs: dict, aux: list) -> None:
    """Unlink every segment still tracked (idempotent, container-driven).

    Module-level so a ``weakref.finalize`` can run it without keeping the
    :class:`_Segments` instance alive: the containers are shared with the
    instance, so whatever ``wrap()`` already handed off is gone from them
    and everything else — including segments stranded by a crashed pool
    round or an exception that skipped the normal cleanup — is unlinked
    here.  ``weakref.finalize`` registers itself atexit, so ``/dev/shm``
    is swept even when the driver is torn down mid-run.
    """
    for shm, _ in segs.values():
        try:
            shm.unlink()
        except FileNotFoundError:
            pass
        try:
            shm.close()
        except BufferError:
            pass
    segs.clear()
    for shm in aux:
        try:
            shm.unlink()
        except FileNotFoundError:
            pass
        try:
            shm.close()
        except BufferError:
            pass
    aux.clear()


class _Segments:
    """Shared-memory segments: create, hand out slots, wrap, unlink.

    Result segments become :class:`_ShmArray` views that own their mapping;
    auxiliary segments (statement key tables) stay owned by the driver and
    are released when the run finishes.  A ``weakref.finalize`` guarantees
    the release even when the run dies before reaching it (worker crash
    unwinding past the caller, driver exit): segments are tracked in
    shared containers the finalizer sweeps, so ``/dev/shm`` never leaks.
    """

    def __init__(self, enabled: bool):
        self.enabled = enabled
        self._segs: dict = {}       # unit key -> (shm, shape)
        self._aux: list = []        # driver-owned segments (key tables)
        self._finalizer = weakref.finalize(
            self, _release_segments, self._segs, self._aux)

    def _new(self, nbytes: int):
        if not self.enabled or nbytes <= 0:
            return None
        try:
            from multiprocessing import shared_memory
            return shared_memory.SharedMemory(create=True, size=nbytes)
        except Exception:
            self.enabled = False    # fall back to pickle for the whole run
            return None

    def allocate(self, key, shape) -> bool:
        shm = self._new(int(np.prod(shape)) * 8)
        if shm is None:
            return False
        self._segs[key] = (shm, shape)
        return True

    def publish(self, arr: "np.ndarray") -> Optional[tuple]:
        """Copy a read-only table into a driver-owned segment."""
        shm = self._new(arr.nbytes)
        if shm is None:
            return None
        np.ndarray(arr.shape, dtype=np.int64, buffer=shm.buf)[:] = arr
        self._aux.append(shm)
        return (shm.name, arr.shape[0])

    def slot(self, key, off: int, count: int) -> _Slot:
        if key in self._segs:
            shm, shape = self._segs[key]
            return _Slot(shm=shm.name, shape=shape, off=off, count=count)
        return _Slot(shm=None, shape=(), off=off, count=count)

    def wrap(self, key) -> Optional["np.ndarray"]:
        got = self._segs.pop(key, None)
        if got is None:
            return None
        shm, shape = got
        arr = np.ndarray(shape, dtype=np.int64, buffer=shm.buf).view(_ShmArray)
        arr._shm = shm
        try:
            shm.unlink()
        except FileNotFoundError:
            pass
        return arr

    def release(self) -> None:
        if self._finalizer.alive:
            self._finalizer()   # runs _release_segments exactly once


def _stmt_maps(graph, tiles: dict, segs: _Segments) -> dict:
    """Per-statement :class:`StmtMap` from the merged tile blocks.

    Non-dense key tables are published as read-only shared segments when
    the shm transport is up; with the pickle transport, small tables ship
    inline and large ones leave the map unusable (raw-row fallback).
    """
    from .taskgraph import _coord_keys   # local import: avoid cycle
    maps = {}
    base = 0
    for name in graph.program.statements:
        arr = tiles[name]
        keys, mins, strides = _coord_keys(arr)
        n = arr.shape[0]
        dense = bool(n) and keys[0] == 0 and int(keys[-1]) == n - 1
        inline = None
        keys_shm = None
        if not dense and n:
            keys_shm = segs.publish(keys)
            if keys_shm is None and n <= KEYS_SHIP_LIMIT:
                # pickle fallback: the table rides inline on every edge job
                # of the unit (pool.map pickles jobs independently, so it is
                # duplicated per block) — bounded by KEYS_SHIP_LIMIT and only
                # hit when shared memory is unavailable; larger tables fall
                # back to raw rows mapped in the driver instead
                inline = keys
        maps[name] = StmtMap(mins=mins, strides=strides, dense=dense,
                             base=base, n=n, keys=inline, keys_shm=keys_shm)
        base += n
    return maps


def _gather(results, parts) -> None:
    for key, seq, res in results:
        if not isinstance(res, int):    # pickle transport: res is the rows
            parts[key][seq] = res


def _merge_pickled(parts: dict) -> dict:
    out = {}
    for key, arrs in parts.items():
        if not arrs or arrs[0] is None:     # shm transport: nothing returned
            continue
        if isinstance(arrs[0], tuple):      # mapped edge columns
            out[key] = tuple(
                np.concatenate([a[i] for a in arrs]) if len(arrs) > 1
                else arrs[0][i] for i in (0, 1))
        else:
            out[key] = arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
    return out


def scan_sharded(graph, params: dict, shards: int,
                 pool: Optional[Executor] = None,
                 oversubscribe: int = OVERSUBSCRIBE,
                 use_shm: bool = True,
                 faults: Optional[FaultPlan] = None,
                 recovery: Optional[RetryPolicy] = None) -> ShardedScans:
    """Fan all materialization scans of ``graph`` out across processes.

    Round 0 counts every block exactly (and warms worker nest caches);
    round 1 scans the statement tile blocks; round 2 scans every dependence
    block, dropping self pairs and mapping edge endpoints to global task
    ids inside the workers.  Results stream straight into final-size
    shared-memory segments at precomputed offsets — the merged product is
    byte-identical to the single-process scans by construction: blocks
    partition the outermost scan dimension and land in ascending order.
    ``use_shm=False`` (or any shared-memory failure) falls back to
    returning pickled blocks and concatenating.

    ``pool`` lets callers amortize one ``ProcessPoolExecutor`` over many
    calls (benchmarks, services); by default a pool of ``min(shards,
    cpu_count)`` workers is spawned and torn down per call.

    ``recovery`` (a :class:`~repro.core.edt.recovery.RetryPolicy`) arms
    per-round timeouts, dead-worker detection, and bounded backoff retry:
    a failed block is re-materialized from its :class:`ShardSpec` — scans
    are pure, so the recovered result is byte-identical to the fault-free
    run by construction.  A broken pool is rebuilt when this call owns it.
    ``faults`` injects a seeded :class:`~repro.core.edt.faults.FaultPlan`
    (crash / hang / shm-attach failure per round × job) for testing the
    recovery path; exhausted retries raise
    :class:`~repro.core.edt.recovery.ShardRecoveryError`, never return a
    partial graph, and never leak a ``/dev/shm`` segment.
    """
    plan = plan_shards(graph, params, shards, oversubscribe)
    scans = ShardedScans()
    segs = _Segments(enabled=use_shm)
    own = pool is None and bool(plan.tile_specs or plan.edge_specs)
    n_workers = max(1, min(shards, os.cpu_count() or 1))
    factory = ((lambda: ProcessPoolExecutor(max_workers=n_workers))
               if own else None)
    if own:
        pool = factory()
    rr = dict(policy=recovery, plan=faults, pool_factory=factory)
    try:
        # ---- round 0: exact block counts (parallel; warms worker nests)
        counts: dict = {}
        if segs.enabled and (plan.tile_specs or plan.edge_specs):
            jobs = [_CountJob(s, None) for s in plan.tile_specs]
            for s in plan.edge_specs:
                td = graph.tiled_deps[s.key]
                diag = (_diag_shard_poly(graph, s.key)
                        if td.dep.src == td.dep.tgt else None)
                jobs.append(_CountJob(s, diag))
            res, pool = run_round(_job_count, jobs, pool, round_no=0, **rr)
            for job, n in zip(jobs, res):
                counts[job.spec] = n

        # ---- round 1: tiles
        tile_parts = {}
        tile_jobs = []
        by_unit: dict = {}
        for spec in plan.tile_specs:
            by_unit.setdefault(spec.key, []).append(spec)
        for key, specs in by_unit.items():
            d = specs[0].poly.ndim
            total = sum(counts[s] for s in specs) if counts else None
            use = (total is not None and total
                   and segs.allocate((TILES, key), (total, d)))
            if total == 0:
                scans.tiles[key] = np.empty((0, d), dtype=np.int64)
                continue
            tile_parts[key] = [None] * len(specs)
            if use:
                off = 0
                for s in specs:
                    tile_jobs.append(_TileJob(
                        spec=s, slot=segs.slot((TILES, key), off, counts[s])))
                    off += counts[s]
            else:
                tile_jobs.extend(
                    _TileJob(spec=s, slot=_Slot(None, (), 0, -1))
                    for s in specs)
        if tile_jobs:
            res, pool = run_round(_job_tile, tile_jobs, pool, round_no=1, **rr)
            _gather(res, tile_parts)
        for key, arr in _merge_pickled(tile_parts).items():
            scans.tiles[key] = arr
        for key in list(tile_parts):
            arr = segs.wrap((TILES, key))
            if arr is not None:
                scans.tiles[key] = arr
        for (kind, key), arr in plan.local.items():
            if kind == TILES:
                scans.tiles[key] = arr

        # ---- round 2: edges
        if plan.edge_specs or any(k == EDGES for k, _ in plan.local):
            maps = _stmt_maps(graph, scans.tiles, segs)
            edge_parts = {}
            edge_jobs = []
            by_unit = {}
            for spec in plan.edge_specs:
                by_unit.setdefault(spec.key, []).append(spec)
            mapped: dict = {}
            for key, specs in by_unit.items():
                td = graph.tiled_deps[key]
                smap, tmap = maps[td.dep.src], maps[td.dep.tgt]
                mapped[key] = smap.usable and tmap.usable
                d = specs[0].poly.ndim
                total = sum(counts[s] for s in specs) if counts else None
                if total == 0:
                    z = np.zeros(0, dtype=np.int64)
                    if mapped[key]:
                        scans.edges_idx[key] = (z, z)
                    else:
                        scans.edges_raw[key] = np.empty((0, d),
                                                        dtype=np.int64)
                    continue
                shape = (2, total) if mapped[key] else (total, d)
                use = (total is not None and total
                       and segs.allocate((EDGES, key), shape))
                edge_parts[key] = [None] * len(specs)
                off = 0
                for s in specs:
                    slot = (segs.slot((EDGES, key), off, counts[s])
                            if use else _Slot(None, (), 0, -1))
                    edge_jobs.append(_EdgeJob(
                        spec=s, slot=slot,
                        ns=graph.tilings[td.dep.src].ndim,
                        self_dep=td.dep.src == td.dep.tgt,
                        smap=smap if mapped[key] else None,
                        tmap=tmap if mapped[key] else None))
                    if use:
                        off += counts[s]
            if edge_jobs:
                res, pool = run_round(_job_edge, edge_jobs, pool,
                                      round_no=2, **rr)
                _gather(res, edge_parts)
            for key, res in _merge_pickled(edge_parts).items():
                (scans.edges_idx if isinstance(res, tuple)
                 else scans.edges_raw)[key] = res
            for key in list(edge_parts):
                arr = segs.wrap((EDGES, key))
                if arr is None:
                    continue
                if mapped[key]:
                    scans.edges_idx[key] = (arr[0], arr[1])
                else:
                    scans.edges_raw[key] = arr
            for (kind, key), arr in plan.local.items():
                if kind == EDGES:
                    td = graph.tiled_deps[key]
                    if td.dep.src == td.dep.tgt and arr.shape[0]:
                        ns = graph.tilings[td.dep.src].ndim
                        arr = arr[(arr[:, :ns] != arr[:, ns:]).any(axis=1)]
                    scans.edges_raw[key] = arr
    finally:
        segs.release()
        if own:
            pool.shutdown()
    return scans
