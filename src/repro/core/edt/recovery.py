"""Self-healing responses to injected (or real) pipeline faults.

The response half of the robustness layer (``faults.py`` is the injection
half).  Three recovery mechanisms, one per failure domain:

* **Shard retry with backoff** — :func:`run_round` drives one pool round of
  shard jobs with per-round timeouts, dead-worker detection (a broken pool
  is rebuilt when the caller owns it), and bounded exponential-backoff
  retry.  Shard scans are *pure* functions of their :class:`ShardSpec`, so
  a retried block re-materializes byte-identically by construction — even
  a stale duplicate from a timed-out worker deposits the same bytes.
  Exhausted retries raise :class:`ShardRecoveryError` carrying a
  :class:`FailureReport`, never a partial graph.

* **Poisoned-cone quarantine** — a task-body exception must cancel exactly
  the tasks data-dependent on it.  :func:`poisoned_cone` computes the
  forward closure over flat edge arrays (:func:`cone_from_successors` is
  the closure-world twin for :class:`ThreadedAutodec`);
  :func:`simulate_indexed_resilient` executes an indexed schedule on the
  instrumented Sim, quarantining each failure's cone level-by-level and
  returning a :class:`FailureReport` naming the failed tasks, the poisoned
  cone, and every undrained counter.

* **Stall watchdog** — :class:`Watchdog` heartbeats a monotone progress
  tuple (started/finished counters) from a daemon thread and converts a
  dropped-decrement deadlock or a hung worker into a :class:`StallReport`
  with a counter-state dump instead of an infinite hang.  The device
  executor raises the same report type (:class:`StallError`) when its
  discover sweep reaches a fixpoint with counters undrained.

All report types serialize (``to_json``) so CI can upload them as
artifacts.  See ``docs/robustness.md`` for the failure model and the
recovery guarantees.
"""

from __future__ import annotations

import json
import threading
import time
from concurrent.futures import BrokenExecutor, wait as _fwait
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .executor import Sim
from .faults import FaultPlan


# ------------------------------------------------------------------ reports
@dataclass
class FailureReport:
    """Structured account of a run with task/shard failures.

    ``failed`` holds every ``(key, error repr)`` pair; ``poisoned`` the
    task ids/keys cancelled because they depend on a failure; ``undrained``
    maps each poisoned task to the counter value it was left with (its
    signals that never arrived).  ``context`` names the failure domain
    (``sharded`` / ``threaded`` / ``sim``).
    """

    context: str
    failed: list = field(default_factory=list)
    poisoned: list = field(default_factory=list)
    undrained: dict = field(default_factory=dict)
    executed: int = 0
    total: Optional[int] = None
    attempts: dict = field(default_factory=dict)   # shard -> attempt count

    def summary(self) -> dict:
        return {
            "context": self.context,
            "n_failed": len(self.failed),
            "n_poisoned": len(self.poisoned),
            "n_undrained": len(self.undrained),
            "executed": self.executed,
            "total": self.total,
        }

    def to_json(self) -> str:
        return json.dumps({
            **self.summary(),
            "failed": [[repr(k), e] for k, e in self.failed],
            "poisoned": [repr(t) for t in self.poisoned],
            "undrained": {repr(t): int(c) for t, c in self.undrained.items()},
            "attempts": {repr(k): int(v) for k, v in self.attempts.items()},
        }, sort_keys=True)


@dataclass
class StallReport:
    """Diagnosis of a run that stopped making progress.

    ``undrained`` is the counter-state dump at stall time — exactly the
    tasks whose signals never arrived, with their remaining counts — which
    turns a dropped-decrement deadlock from an infinite hang into a named
    set of suspects.
    """

    context: str
    elapsed: float
    started: int
    finished: int
    in_flight: int
    undrained: dict = field(default_factory=dict)
    note: str = ""

    def summary(self) -> dict:
        return {
            "context": self.context,
            "elapsed": round(self.elapsed, 3),
            "started": self.started,
            "finished": self.finished,
            "in_flight": self.in_flight,
            "n_undrained": len(self.undrained),
            "note": self.note,
        }

    def to_json(self) -> str:
        return json.dumps({
            **self.summary(),
            "undrained": {repr(t): int(c) for t, c in self.undrained.items()},
        }, sort_keys=True)


class StallError(RuntimeError):
    """Execution stalled; ``.report`` is the :class:`StallReport`."""

    def __init__(self, report: StallReport, msg: Optional[str] = None):
        super().__init__(msg or f"execution stalled: {report.summary()}")
        self.report = report


class ShardRecoveryError(RuntimeError):
    """Shard retries exhausted; ``.report`` is the :class:`FailureReport`."""

    def __init__(self, report: FailureReport, msg: Optional[str] = None):
        super().__init__(msg or ("sharded materialization failed after "
                                 f"retries: {report.summary()}"))
        self.report = report


class TaskGroupError(RuntimeError):
    """Exception-group-style aggregate of every task-body failure.

    Carries ``.failures`` — the full ``(task key, exception)`` list — and
    ``.report``, instead of surfacing only the first error and silently
    dropping the rest.
    """

    def __init__(self, failures: list, report: Optional[FailureReport] = None):
        heads = ", ".join(f"{k!r}: {e!r}" for k, e in failures[:4])
        more = f" (+{len(failures) - 4} more)" if len(failures) > 4 else ""
        super().__init__(
            f"{len(failures)} task(s) failed — {heads}{more}")
        self.failures = list(failures)
        self.report = report


class ScheduleValidationError(RuntimeError):
    """A schedule failed the counted-sync validation, with the evidence.

    ``kind`` is one of ``not-ready`` / ``early-ready`` / ``undrained``;
    ``level`` the offending wavefront (``depth`` for end-of-sweep
    undrained counters); ``task_ids`` the offending global task ids;
    ``counters`` a summary of the counter state at detection.
    """

    def __init__(self, kind: str, level: int, task_ids, counters: dict):
        ids = np.asarray(task_ids, dtype=np.int64)
        shown = ids[:8].tolist()
        more = f" (+{ids.size - 8} more)" if ids.size > 8 else ""
        super().__init__(
            "schedule is not the counted-sync execution of this graph: "
            f"{kind} at level {level}, task(s) {shown}{more}; "
            f"counters: {counters}")
        self.kind = kind
        self.level = level
        self.task_ids = ids
        self.counters = counters


# ------------------------------------------------------------- shard retry
@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for shard rounds.

    ``timeout`` is the per-wave wait (seconds) before outstanding jobs are
    declared hung and resubmitted (``None`` waits forever — hang detection
    off).  A fault that fails ``times <= max_retries`` successive attempts
    is recoverable under this policy by construction.
    """

    max_retries: int = 3
    base_delay: float = 0.01
    backoff: float = 2.0
    timeout: Optional[float] = None


def run_round(fn: Callable, jobs: list, pool, *,
              policy: Optional[RetryPolicy] = None,
              plan: Optional[FaultPlan] = None,
              round_no: int = 0,
              pool_factory: Optional[Callable] = None):
    """Run one round of shard jobs with retry/backoff/timeout recovery.

    ``fn`` is a picklable worker entry taking ``(job, fault, attempt)``
    payloads.  Without a policy (and without faults) this is exactly
    ``pool.map`` — the fault-free fast path pays nothing.  With one, jobs
    are submitted individually; failures (worker exceptions, broken pools,
    per-wave timeouts) are retried with exponential backoff up to
    ``max_retries`` attempts each.  A broken pool is torn down and rebuilt
    via ``pool_factory`` when the caller owns it; without a factory a
    broken pool is unrecoverable.  Returns ``(results, pool)`` — results
    in job order, and the (possibly rebuilt) pool for the next round.

    Raises :class:`ShardRecoveryError` with a :class:`FailureReport` when
    any job exhausts its budget — never returns partial results.
    """
    if policy is None and plan is None:
        return list(pool.map(fn, [(j, None, 0) for j in jobs])), pool
    if policy is None:
        policy = RetryPolicy()

    n = len(jobs)
    results = [None] * n
    done = [False] * n
    attempts = [0] * n
    errors: dict[int, list] = {}
    pending = list(range(n))
    dead: list[int] = []
    while pending:
        futs = {}
        submit_err = None
        for i in pending:
            fault = plan.shard_fault(round_no, i) if plan is not None else None
            try:
                futs[pool.submit(fn, (jobs[i], fault, attempts[i]))] = i
            except (BrokenExecutor, RuntimeError) as e:
                submit_err = e
                break
        failed_now: list[tuple[int, BaseException]] = []
        requeued: list[int] = []
        if futs:
            done_set, not_done = _fwait(set(futs), timeout=policy.timeout)
            for f in done_set:
                i = futs[f]
                try:
                    results[i] = f.result()
                    done[i] = True
                except BaseException as e:  # noqa: BLE001 — any worker death
                    failed_now.append((i, e))
            for f in not_done:
                i = futs[f]
                if f.cancel():
                    # never started — it was queued behind a stalled
                    # worker.  The job is blameless: resubmit without
                    # charging its retry budget.
                    requeued.append(i)
                    continue
                failed_now.append((i, TimeoutError(
                    f"shard job {i} (round {round_no}) exceeded the "
                    f"{policy.timeout}s round timeout")))
            if not done_set and not failed_now and requeued \
                    and submit_err is None:
                # dead spin: nothing ran, nothing was charged — every
                # worker is wedged by an abandoned task.  Charge the
                # queued jobs so the budget still bounds total waiting.
                for i in requeued:
                    failed_now.append((i, TimeoutError(
                        f"shard job {i} (round {round_no}) starved: all "
                        "workers wedged past the round timeout")))
                requeued = []
        if submit_err is not None:
            for i in pending:
                if not done[i] and i not in requeued \
                        and all(j != i for j, _ in failed_now):
                    failed_now.append((i, submit_err))
        pending = requeued
        broken = submit_err is not None
        for i, e in failed_now:
            broken = broken or isinstance(e, BrokenExecutor)
            errors.setdefault(i, []).append(e)
            if plan is not None:
                plan.record("shard_failure", (round_no, i), attempts[i], e)
            attempts[i] += 1
            if attempts[i] > policy.max_retries:
                dead.append(i)
            else:
                pending.append(i)
        if dead:
            report = FailureReport(
                context="sharded",
                failed=[((round_no, i), repr(errors[i][-1])) for i in dead],
                executed=sum(done),
                total=n,
                attempts={(round_no, i): attempts[i] for i in errors})
            raise ShardRecoveryError(report)
        if broken:
            if pool_factory is None:
                report = FailureReport(
                    context="sharded",
                    failed=[((round_no, i), "pool broken (caller-owned, "
                             "cannot rebuild)") for i in pending],
                    executed=sum(done), total=n,
                    attempts={(round_no, i): attempts[i] for i in errors})
                raise ShardRecoveryError(report)
            pool.shutdown(wait=False)
            pool = pool_factory()
        if pending:
            worst = max(attempts[i] for i in pending)
            time.sleep(policy.base_delay * policy.backoff ** (worst - 1))
    return results, pool


# ------------------------------------------------------------ poisoned cone
def poisoned_cone(n: int, edge_src, edge_tgt, failed) -> "np.ndarray":
    """Forward closure of ``failed`` over flat edge arrays (failed excluded).

    The exact set of tasks that can never run once the failed tasks stop
    signaling: every task reachable from a failure through the dependence
    edges.  Vectorized BFS over a CSR view — O(V + E) total.
    """
    failed = np.asarray(list(failed), dtype=np.int64)
    if not n or not failed.size:
        return np.zeros(0, dtype=np.int64)
    edge_src = np.asarray(edge_src)
    edge_tgt = np.asarray(edge_tgt)
    order = np.argsort(edge_src, kind="stable")
    es, et = edge_src[order], edge_tgt[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(es, minlength=n), out=indptr[1:])
    seen = np.zeros(n, dtype=bool)
    seen[failed] = True
    frontier = failed
    while frontier.size:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        tot = int(counts.sum())
        if not tot:
            break
        csum = np.cumsum(counts)
        eidx = (np.repeat(starts - (csum - counts), counts)
                + np.arange(tot, dtype=np.int64))
        nxt = np.unique(et[eidx])
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    cone = np.flatnonzero(seen)
    return cone[~np.isin(cone, failed)]


def cone_from_successors(successors: Callable, failed) -> set:
    """Closure-world twin of :func:`poisoned_cone` for ThreadedAutodec.

    ``successors(key) -> iterable of keys``; returns the forward closure
    of ``failed`` (failed keys themselves excluded).
    """
    failed = set(failed)
    seen = set(failed)
    frontier = list(failed)
    while frontier:
        nxt = []
        for k in frontier:
            for s in successors(k):
                if s not in seen:
                    seen.add(s)
                    nxt.append(s)
        frontier = nxt
    return seen - failed


# -------------------------------------------------------------- stall watch
class Watchdog:
    """Progress heartbeat: convert a silent hang into a :class:`StallReport`.

    ``progress()`` returns a tuple of monotone counters (e.g. ``(started,
    finished)``); ``dump()`` returns the undrained-counter dict for the
    report.  A daemon thread samples progress every ``interval`` seconds;
    when the tuple is unchanged for ``stall_timeout`` seconds the
    ``stalled`` event is set and ``report`` is filled in.  ``stop()`` ends
    the thread; entering/exiting as a context manager starts/stops it.
    """

    def __init__(self, progress: Callable[[], tuple],
                 stall_timeout: float = 30.0,
                 interval: Optional[float] = None,
                 context: str = "",
                 dump: Optional[Callable[[], dict]] = None):
        self._progress = progress
        self._dump = dump or (lambda: {})
        self.stall_timeout = stall_timeout
        self.interval = interval if interval is not None else max(
            0.01, stall_timeout / 20.0)
        self.context = context
        self.stalled = threading.Event()
        self.report: Optional[StallReport] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    __enter__ = start

    def __exit__(self, *exc) -> None:
        self.stop()

    def _watch(self) -> None:
        last = self._progress()
        t0 = time.monotonic()
        since = t0
        while not self._stop.wait(self.interval):
            cur = self._progress()
            now = time.monotonic()
            if cur != last:
                last = cur
                since = now
                continue
            if now - since >= self.stall_timeout:
                started, finished = (cur + (0, 0))[:2]
                in_flight = max(0, started - finished)
                self.report = StallReport(
                    context=self.context,
                    elapsed=now - t0,
                    started=int(started), finished=int(finished),
                    in_flight=int(in_flight),
                    undrained=dict(self._dump()),
                    note=(f"no progress for {self.stall_timeout}s — a "
                          "decrement was dropped or a worker is hung"))
                self.stalled.set()
                return


# --------------------------------------------------- resilient Sim execution
@dataclass
class ResilientRun:
    """Result of a quarantined execution: the Sim plus an optional report."""

    sim: Sim
    report: Optional[FailureReport] = None

    @property
    def ok(self) -> bool:
        return self.report is None


def simulate_indexed_resilient(ig, schedule, body: Optional[Callable] = None,
                               workers: int = 4, task_dur: float = 1.0,
                               faults: Optional[FaultPlan] = None) -> ResilientRun:
    """Execute an :class:`IndexedSchedule` with poisoned-cone quarantine.

    The resilient twin of :func:`~repro.core.edt.wavefront.simulate_indexed`:
    ``body(task_id)`` runs per task on the instrumented Sim and may raise.
    A failure cancels exactly its dependent cone — computed from the index
    graph's edge arrays — and execution continues for every task outside
    it.  The quarantine is applied at each level barrier: a level's ids are
    filtered against the poison set accumulated from all earlier levels,
    so the executed set is deterministic regardless of worker count.

    Returns a :class:`ResilientRun`; with no failures the Sim's
    ``exec_order`` is byte-identical to the fault-free
    ``simulate_indexed``.  With failures the report names every failed
    task, the poisoned cone, and each poisoned task's undrained counter
    (its predecessor signals that never arrived).
    """
    n = ig.n
    failed: list[tuple] = []
    errors: list[tuple] = []
    poison = np.zeros(n, dtype=bool)

    sim = Sim(workers, task_dur, setup_cost=0.0)
    run_body = body or (lambda t: None)

    def make_task(tid: int):
        def run() -> None:
            try:
                fault = faults.body_fault(tid) if faults is not None else None
                if fault is not None:
                    faults.record("task_body_error", tid, 0)
                    from .faults import InjectedTaskError
                    raise InjectedTaskError(tid)
                run_body(tid)
            except BaseException as e:  # noqa: BLE001 — quarantine, not wedge
                failed.append((tid, e))
            done()
        return run

    lvl_state = {"i": -1, "remaining": 0}

    def done() -> None:
        lvl_state["remaining"] -= 1
        if lvl_state["remaining"] == 0:
            launch(lvl_state["i"] + 1)

    def launch(i: int) -> None:
        while i < schedule.depth:
            if failed and len(failed) > len(errors):
                # new failures since the last cone update: re-poison
                new = [(t, e) for t, e in failed[len(errors):]]
                errors.extend(new)
                ids = np.asarray([t for t, _ in new], dtype=np.int64)
                poison[poisoned_cone(n, ig.edge_src, ig.edge_tgt, ids)] = True
            lvl = schedule.levels[i]
            live = lvl[~poison[lvl]]
            if live.size:
                lvl_state["i"] = i
                lvl_state["remaining"] = int(live.size)
                sim.make_ready_batch(
                    (int(t), make_task(int(t))) for t in live)
                return
            i += 1

    launch(0)
    sim.run()
    if not failed:
        return ResilientRun(sim)
    if failed and len(failed) > len(errors):
        errors.extend(failed[len(errors):])
        ids = np.asarray([t for t, _ in failed], dtype=np.int64)
        poison[poisoned_cone(n, ig.edge_src, ig.edge_tgt, ids)] = True
    failed_ids = np.asarray([t for t, _ in failed], dtype=np.int64)
    dead = poison.copy()
    dead[failed_ids] = True
    # a poisoned task's counter keeps one unit per predecessor that never
    # signaled — i.e. every pred that itself failed or was poisoned
    missing = np.bincount(ig.edge_tgt[dead[ig.edge_src]], minlength=n)
    poisoned_ids = np.flatnonzero(poison)
    report = FailureReport(
        context="sim",
        failed=[(int(t), repr(e)) for t, e in failed],
        poisoned=poisoned_ids.tolist(),
        undrained={int(t): int(missing[t]) for t in poisoned_ids
                   if missing[t] > 0},
        executed=len(sim.exec_order),
        total=n)
    return ResilientRun(sim, report)
