"""Device-resident wavefront execution: index graphs on the jax/pallas layer.

The host executors (:class:`~repro.core.edt.executor.Sim`, the dict-based
sync models) re-serialize every schedule through Python — fine for counter
semantics, hopeless for driving a million tasks from a device.  This module
is the step ROADMAP calls "feed ``index_graph()`` / wavefront index arrays
into the jax/pallas execution layer directly": the flat arrays the numpy
backend and the sharded engine already produce are packed **once** into
device-resident jax arrays, and the §2 *counted* synchronization model —
predecessor counters decremented by completions, a task ready exactly when
its counter drains — runs as an XLA loop that never returns to host between
wavefronts.

Two sweeps share the packed graph:

* **discover** (no schedule input) — the device derives the frontiers
  itself.  State is ``(indeg, frontier)``; each :func:`jax.lax.while_loop`
  iteration decrements every frontier task's successors and emits the next
  ready frontier from the counters alone.  The decrement is a segment-sum
  over the transpose-CSR edge columns (gather + cumsum + boundary
  difference — XLA's scatter-add is ~10x slower on CPU for million-edge
  graphs), available either as fused jnp ops or as a pallas kernel
  (``use_pallas=True``; ``interpret=True`` on CPU-only hosts, the same
  fallback the ``repro.kernels`` layer uses).  Work is
  ``O(depth * (V + E))`` — the dense-frontier tradeoff every fixed-shape
  runtime makes.
* **replay** (schedule packed too) — the million-task path.  Edges are
  pre-sorted by source wavefront, so one :func:`jax.lax.fori_loop` over
  levels touches each edge exactly once (``O(V + E)`` total): a level's
  out-edges are a contiguous slice, sliced at fixed padded width and
  scatter-decremented.  The counters are *checked*, not merely trusted: a
  violation counter accumulates (a) any task whose counter is nonzero when
  its level starts, (b) any task whose counter drained before the level
  preceding its own (it would have been ready earlier — a frontier
  mismatch), and (c) any counter left undrained at the end.  All three at
  zero proves the packed schedule is exactly the counted-model execution —
  the same per-level frontiers ``simulate_indexed`` feeds the host Sim.

:class:`DeviceExecutor` wraps both behind one ``run()``, mirroring the
Sim's observable counters (tasks started/finished, max in-flight, per-level
widths) so ``benchmarks/bench_executor.py`` can price host vs device
dispatch per task.  See ``docs/device_exec.md`` for the array layout and
measured numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np

from .config import UNSET, resolve_execution
from .faults import DROPPED_DECREMENT
from .recovery import ScheduleValidationError, StallError, StallReport
from .taskgraph import IndexedGraph, TiledTaskGraph
from .wavefront import IndexedSchedule, levels_from_array

_I32_MAX = np.iinfo(np.int32).max


def _interpret_default() -> bool:
    import jax

    return jax.default_backend() == "cpu"


# ------------------------------------------------------------------ packing
@dataclass
class DeviceGraph:
    """An :class:`IndexedGraph` as device-resident int32 arrays.

    Successors are CSR by source (the put-loop order: ``succ[indptr[t] :
    indptr[t+1]]`` are task ``t``'s out-edges, lexicographic); the
    transpose columns (``dec_src`` grouped by target via ``dec_ptr``) drive
    the counter decrement as a segment sum.  ``pred_n`` is the §4.3 counter
    init vector.  Everything is int32 — a graph near 2^31 tasks or edges
    does not fit a single device anyway.
    """

    n: int
    n_edges: int
    indptr: "np.ndarray"     # i32[n+1]  CSR row starts, source-major
    succ: "np.ndarray"       # i32[E]    edge targets, source-major lex order
    dec_src: "np.ndarray"    # i32[E]    edge sources, target-major order
    dec_ptr: "np.ndarray"    # i32[n+1]  per-target boundaries into dec_src
    pred_n: "np.ndarray"     # i32[n]    §4.3 predecessor counts


@dataclass
class DeviceSchedule:
    """An :class:`IndexedSchedule` packed for the replay sweep.

    ``order`` concatenates the levels (each level's ids ascend) and is
    padded with the sentinel id ``n`` so every level can be read as one
    fixed-size ``dynamic_slice`` of ``w_pad`` ids; ``task_ptr`` holds the
    level boundaries (two trailing entries pin the one-past-end reads).
    ``lvl_tgt`` holds every edge's *target*, sorted stably by the source's
    level, ``e_pad``-padded likewise — a level's out-edges are the slice
    ``[edge_ptr[l], edge_ptr[l+1])``, so the whole sweep touches each edge
    once.

    ``origin`` (optional, set by the fused executor's packing) carries the
    per-task tile-origin columns — row ``t`` is task ``t``'s iteration-space
    origin (tile coords × tile sizes), with a sentinel row at index ``n``
    whose negative time coordinate masks padded lanes; see
    :func:`~repro.core.edt.fused.pack_origins`.
    """

    depth: int
    w_pad: int               # max level width (slice size for task ids)
    e_pad: int               # max out-edges of any level (slice size)
    order: "np.ndarray"      # i32[n + w_pad], sentinel-padded level concat
    task_ptr: "np.ndarray"   # i32[depth+2]
    lvl_tgt: "np.ndarray"    # i32[E + e_pad], sentinel-padded
    edge_ptr: "np.ndarray"   # i32[depth+1]
    levels: list             # the source IndexedSchedule levels (int64 ids)
    level_of: "np.ndarray"   # int64[n]
    origin: Optional["np.ndarray"] = None   # i32[n+1, ndim] tile origins


def pack_graph(ig: IndexedGraph) -> DeviceGraph:
    """CSR + transpose-CSR + counter-init columns, int32, host-side."""
    n, e = ig.n, ig.n_edges
    if max(n, e) >= _I32_MAX:
        raise ValueError(f"graph too large for int32 device ids: {n=} {e=}")
    order = np.argsort(ig.edge_src, kind="stable")
    succ = ig.edge_tgt[order].astype(np.int32)
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(np.bincount(ig.edge_src, minlength=n), out=indptr[1:])
    torder = np.argsort(ig.edge_tgt, kind="stable")
    dec_src = ig.edge_src[torder].astype(np.int32)
    dec_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(ig.pred_n, out=dec_ptr[1:])
    return DeviceGraph(n=n, n_edges=e, indptr=indptr, succ=succ,
                       dec_src=dec_src, dec_ptr=dec_ptr,
                       pred_n=ig.pred_n.astype(np.int32))


def pack_schedule(ig: IndexedGraph, schedule: IndexedSchedule,
                  origins: Optional["np.ndarray"] = None) -> DeviceSchedule:
    """Level-major task and edge columns for the O(V+E) replay sweep.

    ``origins`` (from :func:`~repro.core.edt.fused.pack_origins`) attaches
    the fused executor's tile-origin columns so one packed object carries
    everything the fused replay sweep reads.
    """
    n = ig.n
    if max(n, ig.n_edges) >= _I32_MAX:
        raise ValueError(
            f"graph too large for int32 device ids: n={n} e={ig.n_edges}")
    depth = schedule.depth
    widths = np.asarray([lv.size for lv in schedule.levels], dtype=np.int64)
    order = (np.concatenate(schedule.levels).astype(np.int32) if depth
             else np.zeros(0, dtype=np.int32))
    counts = np.bincount(order, minlength=n) if n else np.zeros(0, np.int64)
    if order.shape[0] != n or (n and (counts != 1).any()):
        raise ValueError("schedule is not an exactly-once permutation of "
                         "the graph's task ids")
    w_pad = int(widths.max()) if depth else 1
    task_ptr = np.zeros(depth + 2, dtype=np.int32)
    task_ptr[1:depth + 1] = np.cumsum(widths)
    task_ptr[depth + 1] = n
    lv_src = schedule.level_of[ig.edge_src]
    eorder = np.argsort(lv_src, kind="stable")
    ecounts = np.bincount(lv_src, minlength=max(depth, 1))
    e_pad = max(int(ecounts.max()), 1)
    edge_ptr = np.zeros(depth + 1, dtype=np.int32)
    edge_ptr[1:] = np.cumsum(ecounts[:depth])
    sent = np.int32(n)
    return DeviceSchedule(
        depth=depth, w_pad=w_pad, e_pad=e_pad,
        order=np.concatenate([order, np.full(w_pad, sent, np.int32)]),
        task_ptr=task_ptr,
        lvl_tgt=np.concatenate([ig.edge_tgt[eorder].astype(np.int32),
                                np.full(e_pad, sent, np.int32)]),
        edge_ptr=edge_ptr,
        levels=schedule.levels, level_of=schedule.level_of, origin=origins)


# ----------------------------------------------------------- decrement step
def decrement_reference(indeg, frontier, dec_src, dec_ptr):
    """Pure-NumPy oracle for one counted-sync wavefront step.

    Given the current counters, the frontier mask, and the transpose-CSR
    edge columns: decrement each task's counter by its in-edges from the
    frontier and report which tasks just became ready.  Returns
    ``(new_indeg, newly_ready_mask)``.
    """
    active = frontier[dec_src].astype(np.int32)
    c = np.zeros(active.shape[0] + 1, dtype=np.int32)
    np.cumsum(active, out=c[1:])
    dec = c[dec_ptr[1:]] - c[dec_ptr[:-1]]
    new_indeg = indeg - dec
    return new_indeg, (new_indeg == 0) & (dec > 0)


def make_xla_step():
    """The wavefront step as fused XLA ops, ready to jit.

    Public spelling of the discover sweep's default decrement
    (:func:`_step_xla`): the distributed runtime's device rank engine
    steps each rank's *local* counters through this exact function, so a
    per-rank sweep is observably the single-host sweep restricted to the
    rank's task range (``core/edt/distributed.py``).
    """
    import jax.numpy as jnp

    return _step_xla(jnp)


def _step_xla(jnp):
    """The reference step as fused XLA ops (the default device path)."""

    def step(indeg, frontier, dec_src, dec_ptr):
        active = frontier[dec_src].astype(jnp.int32)
        c = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(active, dtype=jnp.int32)])
        dec = c[dec_ptr[1:]] - c[dec_ptr[:-1]]
        new_indeg = indeg - dec
        return new_indeg, (new_indeg == 0) & (dec > 0)

    return step


def make_pallas_step(n: int, n_edges: int, interpret: Optional[bool] = None):
    """The wavefront step as one pallas kernel (decrement + frontier emit).

    The kernel reads the counters, the frontier, and the transpose-CSR edge
    columns as whole-array blocks and writes the decremented counters plus
    the newly-ready mask.  On CPU-only hosts it runs under
    ``interpret=True`` (the container default, matching ``repro.kernels``);
    on a real TPU the same body compiles, though a production kernel would
    tile the edge columns through VMEM (see docs/device_exec.md).  Raises
    ``RuntimeError`` when the installed jax has no pallas — callers fall
    back to the XLA step, which is observably identical
    (tests/test_device_exec.py asserts it against
    :func:`decrement_reference`).
    """
    # compat imports jax at module scope; defer so that importing this
    # module (and therefore repro.core.edt, incl. in every ProcessPool
    # worker of the sharded engine) stays jax-free on the host-only paths
    from ... import compat

    pl = compat.pallas()
    if pl is None:
        raise RuntimeError(
            "this jax build has no pallas module; use the default XLA step "
            "(DeviceExecutor(use_pallas=False)) — it is observably identical")
    import jax
    import jax.numpy as jnp

    if interpret is None:
        interpret = _interpret_default()
    if n_edges == 0:
        # zero-length blocks break the pallas interpreter, and an edgeless
        # graph has a trivial step: nothing decrements, nothing becomes ready
        def step(indeg, frontier, dec_src, dec_ptr):
            return indeg, jnp.zeros(n, jnp.bool_)

        return step

    def kernel(indeg_ref, frontier_ref, dec_src_ref, dec_ptr_ref,
               out_indeg_ref, newly_ref):
        indeg = indeg_ref[...]
        active = frontier_ref[...][dec_src_ref[...]].astype(jnp.int32)
        c = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(active, dtype=jnp.int32)])
        ptr = dec_ptr_ref[...]
        dec = c[ptr[1:]] - c[ptr[:-1]]
        new_indeg = indeg - dec
        out_indeg_ref[...] = new_indeg
        newly_ref[...] = (new_indeg == 0) & (dec > 0)

    call = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)),
        interpret=interpret,
    )

    def step(indeg, frontier, dec_src, dec_ptr):
        return call(indeg, frontier, dec_src, dec_ptr)

    return step


# ---------------------------------------------------------------- diagnosis
def _diagnose_replay(dg: DeviceGraph, ds: DeviceSchedule):
    """Host-side replay of the on-device validation, naming the offenders.

    The device sweep accumulates violation *counts* (cheap scalars inside
    the XLA loop); when any is nonzero this NumPy twin re-walks the levels
    with the identical check order — (a) level tasks not ready, (b) next
    level ready early, (c) end-of-sweep undrained counters — and returns
    ``(kind, level, offending task ids, counter state)`` for the first
    violation, so the raised error carries evidence, not just totals.
    """
    indeg = dg.pred_n.astype(np.int64).copy()
    indptr = dg.indptr.astype(np.int64)
    succ = dg.succ.astype(np.int64)
    for level, ids in enumerate(ds.levels):
        bad = ids[indeg[ids] != 0]
        if bad.size:
            return "not-ready", level, bad, indeg
        if level + 1 < ds.depth:
            nxt = ds.levels[level + 1]
            early = nxt[indeg[nxt] == 0]
            if early.size:
                return "early-ready", level + 1, early, indeg
        starts = indptr[ids]
        counts = indptr[ids + 1] - starts
        tot = int(counts.sum())
        if tot:
            csum = np.cumsum(counts)
            eidx = (np.repeat(starts - (csum - counts), counts)
                    + np.arange(tot, dtype=np.int64))
            np.subtract.at(indeg, succ[eidx], 1)
    und = np.flatnonzero(indeg != 0)
    return "undrained", ds.depth, und, indeg


def _counter_summary(indeg: "np.ndarray") -> dict:
    und = np.flatnonzero(indeg != 0)
    return {
        "tasks": int(indeg.shape[0]),
        "undrained": int(und.size),
        "undrained_ids": und[:32].tolist(),
        "max_residual": int(indeg[und].max()) if und.size else 0,
    }


# ----------------------------------------------------------------- counters
@dataclass
class DeviceCounters:
    """The Sim-observable counters, measured on device.

    ``tasks_started``/``tasks_finished`` mirror the Sim's dispatch counts
    (on the device every started wavefront task finishes within its level);
    ``max_in_flight`` is the widest wavefront — what the Sim's
    ``inflight_tasks`` gauge peaks at once workers outnumber the frontier;
    ``level_widths`` are the per-level batch sizes ``make_ready_ids`` would
    see on the host.
    """

    tasks_started: int
    tasks_finished: int
    max_in_flight: int
    depth: int
    level_widths: "np.ndarray"

    def summary(self) -> dict:
        n = self.tasks_started
        return {"tasks_started": n,
                "tasks_finished": self.tasks_finished,
                "max_in_flight": self.max_in_flight,
                "depth": self.depth,
                "avg_width": n / max(1, self.depth)}


@dataclass
class DeviceRun:
    """Result of one device sweep: frontiers + counters, host-side.

    In discover mode ``levels``/``level_of`` are *computed* by the sweep;
    in replay mode they are the input schedule's own arrays, returned only
    after the on-device violation counters proved the schedule is exactly
    the counted-model execution — so "the frontiers match" is established
    by that validation, not by comparing these arrays back to their
    source.
    """

    mode: str                  # "discover" | "replay"
    levels: list               # int64 id arrays per level — the frontiers
    level_of: "np.ndarray"     # int64[n]
    counters: DeviceCounters

    @property
    def exec_order(self) -> "np.ndarray":
        """Global task ids in execution order (level-major, ids ascending
        within a level) — the Sim's ``exec_order`` for the same schedule."""
        if not self.levels:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(self.levels)


# ---------------------------------------------------------------- executor
class DeviceExecutor:
    """Counted-sync execution of an index graph on the jax layer.

    Construct from a :class:`TiledTaskGraph` (``params`` required;
    ``config=``/``session=`` drive the generation scans — shard fan-out,
    pool, recovery; a session serves the graph from its cache) or directly
    from an :class:`IndexedGraph`.  The per-call
    ``shards=``/``parallel=``/``pool=``/``faults=`` kwargs are the
    deprecated spelling of the same config; ``config.faults`` also arms
    execution-side injection (dropped decrements) exactly as the old
    ``faults=`` did.  With ``schedule=`` (an :class:`IndexedSchedule`,
    e.g. from ``synthesize_indexed``) the O(V+E) replay sweep runs and
    *validates* the schedule against the counters; without it the discover
    sweep derives the frontiers on device.  ``packed=(DeviceGraph,
    DeviceSchedule | None)`` skips the host-side packing entirely — the
    graph cache hands its stored device columns through here, so a warm
    executor build is allocation-free.  ``use_pallas=True`` routes the
    discover decrement through the pallas kernel (``interpret=`` overrides
    the CPU auto-fallback).

    ``run()`` returns a :class:`DeviceRun` whose ``levels`` are
    byte-identical to ``synthesize_indexed``'s for the same graph and whose
    ``exec_order`` matches what ``simulate_indexed`` records on the host
    Sim — asserted across backends and shard counts by
    ``tests/test_device_exec.py``.
    """

    def __init__(self, graph: Union[TiledTaskGraph, IndexedGraph],
                 params: Optional[dict] = None, *,
                 schedule: Optional[IndexedSchedule] = None,
                 shards=UNSET, parallel=UNSET, pool=UNSET, faults=UNSET,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 config=None, session=None, packed=None):
        cfg, sess = resolve_execution(
            config, session, stacklevel=3,
            legacy=dict(shards=shards, parallel=parallel, pool=pool,
                        faults=faults))
        if isinstance(graph, TiledTaskGraph):
            if params is None:
                raise TypeError("params required with a TiledTaskGraph")
            ig = (sess.index_graph(graph, params) if sess is not None
                  else graph._index_graph_cfg(params, cfg))
        else:
            ig = graph
        self.faults = cfg.faults
        if packed is not None and schedule is not None:
            raise TypeError("pass schedule= or packed=, not both")
        if use_pallas and (schedule is not None
                           or (packed is not None and packed[1] is not None)):
            raise TypeError(
                "use_pallas applies to the discover sweep only; the replay "
                "sweep's decrement is a per-level scatter, not the pallas "
                "wavefront kernel — drop schedule= to price the kernel")
        self.ig = ig
        if packed is not None:
            self.dg, self.ds = packed
        else:
            self.dg = pack_graph(ig)
            self.ds = (pack_schedule(ig, schedule)
                       if schedule is not None else None)
        self.use_pallas = use_pallas
        self.interpret = interpret
        # compiled sweeps + uploaded arrays, built lazily on the first run()
        # and reused after — repeat runs pay dispatch, not jit, cost
        self._discover_fn = None
        self._replay_fn = None
        if use_pallas:  # resolve (and fail) eagerly, not mid-sweep
            self._pallas_step = make_pallas_step(
                self.dg.n, self.dg.n_edges, interpret)

    # ------------------------------------------------------------- sweeps
    def run(self) -> DeviceRun:
        if self.dg.n == 0:
            counters = DeviceCounters(0, 0, 0, 0, np.zeros(0, np.int64))
            return DeviceRun("replay" if self.ds is not None else "discover",
                             [], np.zeros(0, np.int64), counters)
        if self.ds is not None:
            return self._run_replay()
        return self._run_discover()

    def _run_discover(self) -> DeviceRun:
        import jax
        import jax.numpy as jnp

        dg = self.dg
        n = dg.n
        if self._discover_fn is None:
            step = (self._pallas_step if self.use_pallas else _step_xla(jnp))
            dec_src = jnp.asarray(dg.dec_src)
            dec_ptr = jnp.asarray(dg.dec_ptr)

            def cond(state):
                return state[1].any()

            def body(state):
                indeg, frontier, level, level_of, started, maxw = state
                w = frontier.sum().astype(jnp.int32)
                level_of = jnp.where(frontier, level, level_of)
                indeg, newly = step(indeg, frontier, dec_src, dec_ptr)
                return (indeg, newly, level + 1, level_of, started + w,
                        jnp.maximum(maxw, w))

            self._discover_fn = jax.jit(
                lambda s: jax.lax.while_loop(cond, body, s))
        pred_host = dg.pred_n
        if self.faults is not None:
            # DROPPED_DECREMENT: the counter is initialized one too high,
            # so the matching signal "never arrives" — the exact state a
            # lost decrement leaves behind in the counted model
            dropped = [int(t) for t in self.faults.dropped_tasks()]
            if dropped:
                pred_host = pred_host.copy()
                for t in dropped:
                    pred_host[t] += 1
                    self.faults.record(DROPPED_DECREMENT, t, 0)
        pred = jnp.asarray(pred_host)
        init = (pred, pred == 0, jnp.int32(0),
                jnp.full(n, -1, jnp.int32), jnp.int32(0), jnp.int32(0))
        out = self._discover_fn(init)
        indeg, _, depth, level_of, started, maxw = (np.asarray(x) for x in out)
        started = int(started)
        if started != n:
            # the frontier emptied with counters undrained: a cycle or a
            # dropped decrement.  Not an infinite hang — the sweep reached
            # a fixpoint — so diagnose it: the undrained counters name
            # exactly the tasks whose signals never arrived.
            und = np.flatnonzero(indeg != 0)
            report = StallReport(
                context="device-discover", elapsed=0.0,
                started=started, finished=started,
                in_flight=0,
                undrained={int(t): int(indeg[t]) for t in und[:1024]},
                note=("counted-sync sweep reached a fixpoint with "
                      f"{und.size} counter(s) undrained — the task graph "
                      "has a cycle or a decrement was dropped"))
            raise StallError(report, msg=(
                f"counted-sync sweep deadlocked: {started}/{n} tasks became "
                f"ready — the task graph has a cycle or a decrement was "
                f"dropped; undrained: {und[:8].tolist()}"
                + (f" (+{und.size - 8} more)" if und.size > 8 else "")))
        level_of = level_of.astype(np.int64)
        levels = levels_from_array(level_of)
        widths = np.asarray([lv.size for lv in levels], dtype=np.int64)
        counters = DeviceCounters(started, started, int(maxw), int(depth),
                                  widths)
        return DeviceRun("discover", levels, level_of, counters)

    def _run_replay(self) -> DeviceRun:
        import jax
        import jax.numpy as jnp
        from jax import lax

        dg, ds = self.dg, self.ds
        n, depth, w_pad, e_pad = dg.n, ds.depth, ds.w_pad, ds.e_pad
        if self._replay_fn is None:
            op = jnp.asarray(ds.order)
            tp = jnp.asarray(ds.task_ptr)
            ep = jnp.asarray(ds.edge_ptr)
            tg = jnp.asarray(ds.lvl_tgt)

            @jax.jit
            def sweep(indeg):
                aw = jnp.arange(w_pad, dtype=jnp.int32)
                ae = jnp.arange(e_pad, dtype=jnp.int32)

                def body(level, carry):
                    indeg, not_ready, early, maxw = carry
                    w = tp[level + 1] - tp[level]
                    ids = lax.dynamic_slice(op, (tp[level],), (w_pad,))
                    # (a) every task of this level must have a drained
                    # counter when it starts
                    not_ready += jnp.sum(
                        jnp.where(aw < w, indeg[ids] != 0, False))
                    # (b) no task of the NEXT level may be ready before this
                    # level's decrements run — it would have been in an
                    # earlier frontier.  Checked level by level, this pins
                    # every task's drain to exactly the level before its own.
                    nw = tp[level + 2] - tp[level + 1]
                    nids = lax.dynamic_slice(op, (tp[level + 1],), (w_pad,))
                    early += jnp.sum(
                        jnp.where(aw < nw, indeg[nids] == 0, False))
                    # decrement this wavefront's out-edges (contiguous slice)
                    ec = ep[level + 1] - ep[level]
                    tgts = lax.dynamic_slice(tg, (ep[level],), (e_pad,))
                    tgts = jnp.where(ae < ec, tgts, n)
                    indeg = indeg.at[tgts].add(-1)
                    return indeg, not_ready, early, jnp.maximum(maxw, w)

                z = jnp.int32(0)
                indeg, not_ready, early, maxw = lax.fori_loop(
                    0, depth, body, (indeg, z, z, z))
                # (c) every counter fully consumed: each edge signaled once
                undrained = jnp.sum(indeg[:n] != 0)
                return not_ready, early, undrained, maxw

            self._replay_fn = sweep
        # slot n swallows sentinel/padded decrements and gathers
        indeg0 = jnp.concatenate([jnp.asarray(dg.pred_n),
                                  jnp.zeros(1, jnp.int32)])
        not_ready, early, undrained, maxw = (
            int(x) for x in self._replay_fn(indeg0))
        if not_ready or early or undrained:
            # the device counted the violations; re-derive the offenders
            # host-side so the error carries evidence, not just totals
            kind, level, ids, indeg = _diagnose_replay(dg, ds)
            counters = _counter_summary(indeg)
            counters.update(device_not_ready=not_ready, device_early=early,
                            device_undrained=undrained)
            raise ScheduleValidationError(kind, level, ids, counters)
        widths = np.asarray([lv.size for lv in ds.levels], dtype=np.int64)
        counters = DeviceCounters(n, n, int(maxw), depth, widths)
        return DeviceRun("replay", ds.levels, ds.level_of, counters)
