"""Distributed counted-sync runtime: rank-owned ranges, message decrements.

The last scaling axis in ROADMAP: PR 4 made *generation* parallel and
PR 5/8 made *execution* device-resident, but everything still ran in one
process.  This module crosses the host boundary with TaskTorrent's
active-message spelling of the paper's §2 counted model (PAPERS.md): the
:class:`~repro.core.edt.taskgraph.IndexedGraph` is partitioned by
**contiguous global task-id range** — the same deterministic divmod split
``plan_shards`` uses for scan blocks — and each rank owns exactly the
counters of its range.  A dependence edge then lowers to one of two
decrements:

* **local edge** (source and target on one rank) — an in-place counter
  decrement, exactly the single-host sweep;
* **cross-rank edge** — an *active message*: the owning rank of the source
  batches ``(target id, source level + 1)`` pairs per destination rank and
  sends them; the receiving rank's mailbox admits each batch exactly once
  (per-channel sequence numbers) and applies it as a counter decrement.

Counters alone decide readiness — no global schedule, no level barrier
between ranks.  Ranks run fully asynchronously (the event-driven dispatch
of Brown et al.): each processes whatever is ready, ships its outbox, and
blocks on its inbox only when its own frontier is empty.  Termination is
local and exact: a rank is done when it has started all ``n_local`` of its
tasks *and* received all ``expected_in`` cross-rank decrements (both known
at partition time), so no distributed termination detection is needed.

Wavefront levels stay exact without synchrony because decrements carry
them: a task's level is ``max(pred level) + 1``, and every decrement
(local gather or message) delivers its source's final level + 1 into a
``np.maximum.at`` — order-independent, so the merged per-rank levels are
byte-identical to single-host :func:`~repro.core.edt.wavefront
.schedule_from_graph` / ``DeviceExecutor`` discover, and the union of
frontiers replays through ``simulate_indexed`` identically
(``tests/test_distributed.py``).

Two rank engines share the partition:

* ``engine="numpy"`` — the sparse frontier sweep (CSR gather + unique
  decrement, the ``_level_array`` machinery per rank).  Fully async; the
  only engine allowed on the ``processes`` transport.  The 10M+-task path.
* ``engine="device"`` / ``use_pallas=True`` — each rank steps its local
  dense counters through the *exact* decrement step the single-host
  :class:`~repro.core.edt.device.DeviceExecutor` discover sweep jits
  (:func:`~repro.core.edt.device.make_xla_step` /
  :func:`~repro.core.edt.device.make_pallas_step`).  Level-synchronous by
  construction (superstep index == wavefront level), so it requires the
  barriered ``inline`` transport.

Transports: ``inline`` round-robins every rank in one process (deterministic,
test- and device-friendly); ``processes`` spawns one OS process per rank
with multiprocessing queues as the message fabric (``start_method="spawn"``
safe; ``jax.distributed`` multi-controller would slot in at this seam —
the engines only ever see :class:`MsgBatch` objects).

Failure semantics extend PR 6 (``docs/robustness.md``): ``RANK_CRASH`` and
``MESSAGE_LOSS`` faults inject a dying rank / a dropped decrement batch; a
lost batch leaves ``received < expected_in`` and surfaces as a
:class:`~repro.core.edt.recovery.StallReport` (worker inbox timeout or the
inline fixpoint check), a dead rank as a :class:`RankFailureError`; under a
:class:`~repro.core.edt.recovery.RetryPolicy` the driver re-runs the
attempt — the sweep is a pure function of the partition, so the recovered
frontiers are byte-identical by construction.  A
:class:`~repro.core.edt.recovery.Watchdog` guards the process driver
against silent hangs.  See ``docs/distributed.md``.
"""
from __future__ import annotations

import os
import time
from collections import deque
from dataclasses import dataclass, field
from queue import Empty
from typing import Optional, Union

import numpy as np

from .config import resolve_execution
from .faults import MESSAGE_LOSS, RANK_CRASH, FaultPlan, InjectedRankCrash
from .recovery import FailureReport, StallError, StallReport, Watchdog
from .taskgraph import IndexedGraph, TiledTaskGraph
from .wavefront import levels_from_array

#: Seconds a rank waits on an empty inbox (and the driver's watchdog base)
#: before declaring the run stalled, when no RetryPolicy timeout is set.
DEFAULT_STALL_TIMEOUT = 20.0


class RankFailureError(RuntimeError):
    """A rank died mid-run; ``.report`` is the :class:`FailureReport`."""

    def __init__(self, report: FailureReport, msg: Optional[str] = None):
        super().__init__(msg or ("distributed rank failed: "
                                 f"{report.summary()}"))
        self.report = report


# --------------------------------------------------------------- partition
def plan_ranks(n: int, ranks: int) -> "np.ndarray":
    """Contiguous task-id range boundaries: ``bounds[k] .. bounds[k+1]``.

    The same deterministic divmod split :func:`~repro.core.edt.shard
    .plan_shards` uses for outer-dim blocks — boundaries depend only on
    ``(n, ranks)``, never on scheduling, so every attempt (and every
    retry) partitions identically.
    """
    if ranks < 1:
        raise ValueError(f"need at least one rank, got {ranks}")
    q, r = divmod(n, ranks)
    sizes = np.full(ranks, q, dtype=np.int64)
    sizes[:r] += 1
    bounds = np.zeros(ranks + 1, dtype=np.int64)
    np.cumsum(sizes, out=bounds[1:])
    return bounds


@dataclass
class RankSlice:
    """One rank's share of the graph — picklable, spawn-safe.

    ``indeg`` is the full §4.3 counter init (cross-rank predecessors
    included — a missing remote signal must keep the counter up).  Local
    out-edges are CSR with *local* target indices; cross-rank out-edges
    are CSR with *global* target ids (the message payload).
    ``expected_in`` is the exact number of cross-rank decrements this
    rank will receive — the local termination condition.
    """

    rank: int
    ranks: int
    lo: int
    hi: int
    bounds: "np.ndarray"      # i64[ranks+1] ownership boundaries
    indeg: "np.ndarray"       # i64[nl] full in-degree counter init
    l_indptr: "np.ndarray"    # i64[nl+1] CSR over local sources
    l_tgt: "np.ndarray"       # i64[El]   local target indices
    r_indptr: "np.ndarray"    # i64[nl+1] CSR over local sources
    r_tgt: "np.ndarray"       # i64[Er]   global target ids (other ranks)
    expected_in: int

    @property
    def n_local(self) -> int:
        return self.hi - self.lo


def partition_graph(ig: IndexedGraph, ranks: int) -> list[RankSlice]:
    """Split an index graph into per-rank slices (host-side, one pass).

    Edges are grouped by source rank (one stable argsort, shared with the
    single-host CSR packing), then split local/cross per rank; the
    per-rank arrays are views/copies of the grouped columns, so the
    partition is deterministic and byte-reproducible.
    """
    n = ig.n
    bounds = plan_ranks(n, ranks)
    order = np.argsort(ig.edge_src, kind="stable")
    es = ig.edge_src[order]
    et = ig.edge_tgt[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(es, minlength=n), out=indptr[1:])
    tr = np.searchsorted(bounds, et, side="right") - 1
    sr = np.searchsorted(bounds, es, side="right") - 1
    cross = sr != tr
    exp_in = (np.bincount(tr[cross], minlength=ranks) if cross.any()
              else np.zeros(ranks, dtype=np.int64))
    slices = []
    for k in range(ranks):
        lo, hi = int(bounds[k]), int(bounds[k + 1])
        nl = hi - lo
        e0, e1 = int(indptr[lo]), int(indptr[hi])
        tgt = et[e0:e1]
        row = indptr[lo:hi + 1] - e0
        src_of = np.repeat(np.arange(nl, dtype=np.int64), np.diff(row))
        local = (tgt >= lo) & (tgt < hi)
        ls, lt = src_of[local], tgt[local] - lo
        rs, rt = src_of[~local], tgt[~local]
        l_indptr = np.zeros(nl + 1, dtype=np.int64)
        np.cumsum(np.bincount(ls, minlength=nl), out=l_indptr[1:])
        r_indptr = np.zeros(nl + 1, dtype=np.int64)
        np.cumsum(np.bincount(rs, minlength=nl), out=r_indptr[1:])
        slices.append(RankSlice(
            rank=k, ranks=ranks, lo=lo, hi=hi, bounds=bounds,
            indeg=ig.pred_n[lo:hi].astype(np.int64),
            l_indptr=l_indptr, l_tgt=lt, r_indptr=r_indptr, r_tgt=rt,
            expected_in=int(exp_in[k])))
    return slices


# ---------------------------------------------------------------- messages
@dataclass
class MsgBatch:
    """One active-message batch: decrements for one destination rank.

    ``tgt`` holds global target ids, ``lvl`` the candidate wavefront
    levels (source level + 1) riding along so the receiver's
    ``np.maximum.at`` keeps levels exact without any barrier.  ``seq``
    orders the ``src -> dst`` channel for exactly-once admission.
    """

    src: int
    dst: int
    seq: int
    tgt: "np.ndarray"
    lvl: "np.ndarray"


class Mailbox:
    """Exactly-once admission of decrement batches, per source channel.

    Channels are FIFO (queue transports preserve order), so a batch is a
    duplicate iff its sequence number is behind the channel cursor —
    re-sent or replayed batches are dropped and counted, never applied
    twice (a double decrement would corrupt the §2 counter invariant).
    """

    def __init__(self, ranks: int):
        self._next = [0] * ranks
        self.duplicates = 0
        self.admitted_batches = 0
        self.admitted_msgs = 0

    def admit(self, batch: MsgBatch) -> bool:
        if batch.seq < self._next[batch.src]:
            self.duplicates += 1
            return False
        self._next[batch.src] = batch.seq + 1
        self.admitted_batches += 1
        self.admitted_msgs += int(batch.tgt.shape[0])
        return True


@dataclass
class RankStats:
    """Per-rank observables of one distributed run (picklable)."""

    rank: int
    n_local: int
    started: int
    supersteps: int
    msgs_out: int
    msgs_in: int
    batches_out: int
    batches_in: int
    duplicates: int
    seconds: float


# ----------------------------------------------------------- rank engines
def _gather(indptr, tgt, front, level):
    """All out-edges of ``front`` through a CSR: (targets, src level + 1)."""
    starts = indptr[front]
    counts = indptr[front + 1] - starts
    tot = int(counts.sum())
    if not tot:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    csum = np.cumsum(counts)
    eidx = (np.repeat(starts - (csum - counts), counts)
            + np.arange(tot, dtype=np.int64))
    cand = np.repeat(level[front] + 1, counts)
    return tgt[eidx], cand


class RankEngine:
    """One rank's counted sweep — sparse numpy frontier, fully async.

    The per-rank twin of the ``_level_array`` Kahn sweep: ready local
    tasks are processed in whatever order their counters drain (batch
    FIFO), local out-edges decrement in place, cross-rank out-edges batch
    into the outbox.  Levels max-propagate through the carried
    ``source level + 1`` candidates, so the result is independent of
    message arrival order — the asynchrony never shows in the output.
    """

    def __init__(self, sl: RankSlice):
        self.sl = sl
        self.indeg = sl.indeg.copy()
        self.level = np.zeros(sl.n_local, dtype=np.int64)
        self.pending: deque = deque()
        roots = np.flatnonzero(self.indeg == 0)
        if roots.size:
            self.pending.append(roots)
        self.started = 0
        self.received = 0
        self.mail = Mailbox(sl.ranks)
        self.out_seq = [0] * sl.ranks
        self.supersteps = 0
        self.msgs_out = 0
        self.batches_out = 0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- state
    @property
    def done(self) -> bool:
        return (self.started == self.sl.n_local
                and self.received == self.sl.expected_in)

    @property
    def pending_size(self) -> int:
        return sum(int(a.size) for a in self.pending)

    def undrained(self) -> dict:
        und = np.flatnonzero(self.indeg != 0)
        return {int(t + self.sl.lo): int(self.indeg[t]) for t in und[:1024]}

    def stats(self) -> RankStats:
        return RankStats(
            rank=self.sl.rank, n_local=self.sl.n_local, started=self.started,
            supersteps=self.supersteps, msgs_out=self.msgs_out,
            msgs_in=self.mail.admitted_msgs, batches_out=self.batches_out,
            batches_in=self.mail.admitted_batches,
            duplicates=self.mail.duplicates,
            seconds=time.perf_counter() - self._t0)

    # ------------------------------------------------------------- sweep
    def _drain(self, tgt_local, cand) -> None:
        """Apply decrements + level candidates; queue newly-ready tasks."""
        np.maximum.at(self.level, tgt_local, cand)
        touched, dec = np.unique(tgt_local, return_counts=True)
        self.indeg[touched] -= dec
        newly = touched[self.indeg[touched] == 0]
        if newly.size:
            self.pending.append(newly)

    def superstep(self) -> list[MsgBatch]:
        """Process every currently-ready local task; return the outbox."""
        if not self.pending:
            return []
        front = (self.pending.popleft() if len(self.pending) == 1
                 else np.concatenate(list(self.pending)))
        self.pending.clear()
        self.started += int(front.size)
        self.supersteps += 1
        sl = self.sl
        lt, lc = _gather(sl.l_indptr, sl.l_tgt, front, self.level)
        rt, rc = _gather(sl.r_indptr, sl.r_tgt, front, self.level)
        if lt.size:
            self._drain(lt, lc)
        out: list[MsgBatch] = []
        if rt.size:
            dst = np.searchsorted(sl.bounds, rt, side="right") - 1
            order = np.argsort(dst, kind="stable")
            rt, rc, dst = rt[order], rc[order], dst[order]
            cuts = np.flatnonzero(np.diff(dst)) + 1
            firsts = np.concatenate([[0], cuts])
            for t, c, at in zip(np.split(rt, cuts), np.split(rc, cuts),
                                firsts):
                d = int(dst[at])
                out.append(MsgBatch(src=sl.rank, dst=d, seq=self.out_seq[d],
                                    tgt=t, lvl=c))
                self.out_seq[d] += 1
                self.msgs_out += int(t.size)
                self.batches_out += 1
        return out

    def apply(self, batch: MsgBatch) -> None:
        """Message-triggered decrement: admit exactly once, then drain."""
        if not self.mail.admit(batch):
            return
        self.received += int(batch.tgt.shape[0])
        self._drain(batch.tgt - self.sl.lo, batch.lvl)


class DeviceRankEngine:
    """BSP rank engine on the device decrement step — inline transport only.

    Steps the rank's *local* dense counters through the exact function the
    single-host :class:`~repro.core.edt.device.DeviceExecutor` discover
    sweep jits (:func:`make_xla_step`, or :func:`make_pallas_step` under
    ``use_pallas=True``) over the local transpose-CSR edge columns.
    Cross-rank decrements apply between steps.  Because the inline
    transport barriers every rank each round, the superstep index *is*
    the global wavefront level (lockstep Kahn), so levels need no carried
    candidates — asserted byte-identical to the async numpy engine by
    ``tests/test_distributed.py``.
    """

    def __init__(self, sl: RankSlice, use_pallas: bool = False,
                 interpret: Optional[bool] = None):
        from .device import make_pallas_step, make_xla_step

        self.sl = sl
        nl = sl.n_local
        self.indeg = sl.indeg.astype(np.int32)
        self.level = np.zeros(nl, dtype=np.int64)
        src_of = np.repeat(np.arange(nl, dtype=np.int64),
                           np.diff(sl.l_indptr))
        torder = np.argsort(sl.l_tgt, kind="stable")
        dec_ptr = np.zeros(nl + 1, dtype=np.int32)
        np.cumsum(np.bincount(sl.l_tgt, minlength=nl), out=dec_ptr[1:])
        self._dec_src_h = src_of[torder].astype(np.int32)
        self._dec_ptr_h = dec_ptr
        self._jax = None
        if use_pallas:
            self._step = make_pallas_step(nl, int(sl.l_tgt.size), interpret)
        else:
            import jax

            self._step = jax.jit(make_xla_step())
        self._next: list = []
        roots = np.flatnonzero(self.indeg == 0)
        if roots.size:
            self._next.append(roots)
        self.round = 0
        self.started = 0
        self.received = 0
        self.mail = Mailbox(sl.ranks)
        self.out_seq = [0] * sl.ranks
        self.supersteps = 0
        self.msgs_out = 0
        self.batches_out = 0
        self._t0 = time.perf_counter()

    @property
    def done(self) -> bool:
        return (self.started == self.sl.n_local
                and self.received == self.sl.expected_in)

    @property
    def pending_size(self) -> int:
        return sum(int(a.size) for a in self._next)

    undrained = RankEngine.undrained
    stats = RankEngine.stats

    def superstep(self) -> list[MsgBatch]:
        """One BSP round: device-step the frontier, emit the outbox.

        Rounds advance even when the frontier is empty (the rank idles a
        wavefront) so the round counter stays the global level index.
        """
        import jax.numpy as jnp

        cur = self.round
        self.round = cur + 1
        if not self._next:
            return []
        ids = (self._next[0] if len(self._next) == 1
               else np.concatenate(self._next))
        self._next = []
        sl = self.sl
        self.level[ids] = cur
        self.started += int(ids.size)
        self.supersteps += 1
        mask = np.zeros(sl.n_local, dtype=bool)
        mask[ids] = True
        new_indeg, newly = self._step(
            jnp.asarray(self.indeg), jnp.asarray(mask),
            jnp.asarray(self._dec_src_h), jnp.asarray(self._dec_ptr_h))
        self.indeg = np.array(new_indeg)
        newly_ids = np.flatnonzero(np.asarray(newly))
        if newly_ids.size:
            self._next.append(newly_ids)
        rt, _ = _gather(sl.r_indptr, sl.r_tgt, ids, self.level)
        out: list[MsgBatch] = []
        if rt.size:
            lvl = np.full(rt.size, cur + 1, dtype=np.int64)
            dst = np.searchsorted(sl.bounds, rt, side="right") - 1
            order = np.argsort(dst, kind="stable")
            rt, lvl, dst = rt[order], lvl[order], dst[order]
            cuts = np.flatnonzero(np.diff(dst)) + 1
            firsts = np.concatenate([[0], cuts])
            for t, c, at in zip(np.split(rt, cuts), np.split(lvl, cuts),
                                firsts):
                d = int(dst[at])
                out.append(MsgBatch(src=sl.rank, dst=d, seq=self.out_seq[d],
                                    tgt=t, lvl=c))
                self.out_seq[d] += 1
                self.msgs_out += int(t.size)
                self.batches_out += 1
        return out

    def apply(self, batch: MsgBatch) -> None:
        if not self.mail.admit(batch):
            return
        self.received += int(batch.tgt.shape[0])
        tl = batch.tgt - self.sl.lo
        touched, dec = np.unique(tl, return_counts=True)
        self.indeg[touched] -= dec.astype(np.int32)
        newly = touched[self.indeg[touched] == 0]
        if newly.size:
            self._next.append(newly)


def _make_engine(sl: RankSlice, engine: str, use_pallas: bool,
                 interpret: Optional[bool]):
    if engine == "numpy":
        return RankEngine(sl)
    if engine == "device":
        return DeviceRankEngine(sl, use_pallas=use_pallas,
                                interpret=interpret)
    raise ValueError(f"unknown rank engine {engine!r} "
                     "(expected 'numpy' or 'device')")


# --------------------------------------------------------------- transports
def _lose_or_send(batch: MsgBatch, send, faults: Optional[FaultPlan],
                  attempt: int, dropped: set, record: bool) -> None:
    """Deliver one batch, dropping the first per faulted channel/attempt."""
    if faults is not None:
        f = faults.message_fault(batch.src, batch.dst)
        if (f is not None and attempt < f.times
                and (batch.src, batch.dst) not in dropped):
            dropped.add((batch.src, batch.dst))
            if record:
                faults.record(MESSAGE_LOSS, (batch.src, batch.dst), attempt)
            return
    send(batch)


def _stall_report(engines, context: str, elapsed: float) -> StallReport:
    und: dict = {}
    for e in engines:
        und.update(e.undrained())
    started = sum(e.started for e in engines)
    missing = sum(e.sl.expected_in - e.received for e in engines)
    return StallReport(
        context=context, elapsed=elapsed, started=started, finished=started,
        in_flight=0, undrained=und,
        note=(f"counted sweep reached a fixpoint with {len(und)} counter(s) "
              f"undrained and {missing} expected cross-rank decrement(s) "
              "missing — a message was lost or the graph has a cycle"))


def _run_inline(slices, engine: str, faults: Optional[FaultPlan],
                attempt: int, use_pallas: bool, interpret):
    """All ranks in one process, round-robin BSP rounds — deterministic."""
    engines = [_make_engine(sl, engine, use_pallas, interpret)
               for sl in slices]
    queues = [deque() for _ in slices]
    dropped: set = set()
    t0 = time.perf_counter()
    while True:
        for eng, q in zip(engines, queues):
            while q:
                eng.apply(q.popleft())
        if all(e.done for e in engines):
            return engines
        moved = False
        for k, eng in enumerate(engines):
            if faults is not None and not eng.done:
                crash = faults.rank_fault(k)
                if (crash is not None and attempt < crash.times
                        and eng.started > 0):
                    faults.record(RANK_CRASH, k, attempt)
                    raise InjectedRankCrash(k, attempt)
            moved = moved or eng.pending_size > 0
            for b in eng.superstep():
                _lose_or_send(b, queues[b.dst].append, faults, attempt,
                              dropped, record=True)
        if not moved and not any(queues):
            raise StallError(_stall_report(
                engines, "distributed-inline", time.perf_counter() - t0))


def _rank_worker(sl: RankSlice, faults: Optional[FaultPlan], attempt: int,
                 inboxes, result_q, timeout: float) -> None:
    """One rank as an OS process (module-level: spawn-start safe).

    Runs the async numpy engine to local termination; an empty frontier
    blocks on the inbox with ``timeout`` as the stall bound — expiring it
    reports a :class:`StallReport` (the message-loss surface) instead of
    hanging.  Injected crashes report (soft) or kill the process (hard);
    the driver converts either into a failed attempt.
    """
    try:
        eng = RankEngine(sl)
        crash = faults.rank_fault(sl.rank) if faults is not None else None
        dropped: set = set()
        t0 = time.perf_counter()
        while not eng.done:
            for b in eng.superstep():
                _lose_or_send(b, inboxes[b.dst].put, faults, attempt,
                              dropped, record=False)
            if crash is not None and attempt < crash.times and eng.started:
                if crash.hard:
                    os._exit(1)
                raise InjectedRankCrash(sl.rank, attempt)
            if eng.done or eng.pending_size:
                continue
            try:
                eng.apply(inboxes[sl.rank].get(timeout=timeout))
            except Empty:
                result_q.put(("stall", sl.rank, _stall_report(
                    [eng], "distributed-rank", time.perf_counter() - t0)))
                return
            while True:
                try:
                    eng.apply(inboxes[sl.rank].get_nowait())
                except Empty:
                    break
        result_q.put(("ok", sl.rank, eng.level, eng.stats()))
    except InjectedRankCrash as e:
        result_q.put(("crash", sl.rank, repr(e)))
    except BaseException as e:  # noqa: BLE001 — any rank death is a report
        result_q.put(("error", sl.rank, repr(e)))


def _rank_failure(kind: str, rank, err, done: int, total: int,
                  attempt: int) -> RankFailureError:
    report = FailureReport(
        context="distributed", failed=[(("rank", rank), err)],
        executed=done, total=total, attempts={("rank", rank): attempt + 1})
    return RankFailureError(report, msg=(
        f"rank {rank} {kind} (attempt {attempt}): {err}"))


def _run_processes(slices, faults: Optional[FaultPlan], attempt: int,
                   timeout: float, start_method: Optional[str]):
    """One OS process per rank, multiprocessing queues as the fabric."""
    import multiprocessing as mp

    ctx = mp.get_context(start_method) if start_method else mp.get_context()
    inboxes = [ctx.Queue() for _ in slices]
    result_q = ctx.Queue()
    procs = [ctx.Process(target=_rank_worker,
                         args=(sl, faults, attempt, inboxes, result_q,
                               timeout),
                         daemon=True)
             for sl in slices]
    results: dict = {}
    wd = Watchdog(progress=lambda: (len(results), 0),
                  stall_timeout=max(5 * timeout, 60.0),
                  context="distributed-driver")
    try:
        for p in procs:
            p.start()
        with wd:
            while len(results) < len(slices):
                if wd.stalled.is_set():
                    raise StallError(wd.report)
                try:
                    msg = result_q.get(timeout=0.2)
                except Empty:
                    for p, sl in zip(procs, slices):
                        if (sl.rank not in results and not p.is_alive()
                                and p.exitcode not in (0, None)):
                            raise _rank_failure(
                                "died", sl.rank, f"exitcode {p.exitcode}",
                                len(results), len(slices), attempt)
                    continue
                kind, rank = msg[0], msg[1]
                if kind == "ok":
                    results[rank] = (msg[2], msg[3])
                elif kind == "stall":
                    raise StallError(msg[2])
                else:
                    raise _rank_failure(kind, rank, msg[2], len(results),
                                        len(slices), attempt)
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        for p in procs:
            p.join(timeout=5.0)
        for q in [*inboxes, result_q]:
            q.cancel_join_thread()
            q.close()
    return results


# ------------------------------------------------------------------ driver
@dataclass
class DistributedRun:
    """Result of one distributed counted-sync run, merged host-side.

    ``levels``/``level_of`` are the union of the per-rank frontiers —
    byte-identical to the single-host discover sweep and to
    ``schedule_from_graph`` for the same graph (the differential suite's
    contract).  ``attempts`` counts retries consumed (0 = clean first
    attempt); ``rank_stats`` carries each rank's task and message volume.
    """

    ranks: int
    engine: str
    transport: str
    levels: list
    level_of: "np.ndarray"
    rank_stats: list = field(default_factory=list)
    attempts: int = 0

    @property
    def n(self) -> int:
        return int(self.level_of.shape[0])

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def exec_order(self) -> "np.ndarray":
        """Global ids in execution order (level-major, ascending within a
        level) — what ``simulate_indexed`` records on the host Sim."""
        if not self.levels:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(self.levels)

    def summary(self) -> dict:
        return {
            "ranks": self.ranks, "engine": self.engine,
            "transport": self.transport, "tasks": self.n,
            "depth": self.depth, "attempts": self.attempts,
            "msgs": sum(s.msgs_out for s in self.rank_stats),
            "batches": sum(s.batches_out for s in self.rank_stats),
            "duplicates": sum(s.duplicates for s in self.rank_stats),
        }


def run_distributed(graph: Union[TiledTaskGraph, IndexedGraph],
                    params: Optional[dict] = None, *,
                    ranks: int = 2,
                    engine: str = "numpy",
                    transport: Optional[str] = None,
                    config=None, session=None,
                    use_pallas: bool = False,
                    interpret: Optional[bool] = None,
                    start_method: Optional[str] = None,
                    timeout: Optional[float] = None) -> DistributedRun:
    """Execute the counted-sync model across ``ranks`` task-range owners.

    Accepts a :class:`TiledTaskGraph` + ``params`` (generation runs under
    ``config=``/``session=`` exactly like :class:`DeviceExecutor` — a
    session serves the index graph from its cache) or a pre-built
    :class:`IndexedGraph`.  ``transport`` defaults to ``"processes"`` for
    the numpy engine and ``"inline"`` for the device engine (which is
    level-synchronous and therefore inline-only).  ``config.faults`` arms
    ``RANK_CRASH``/``MESSAGE_LOSS`` injection; ``config.recovery`` (a
    :class:`RetryPolicy`) retries failed attempts with backoff — attempts
    are pure, so a recovered run is byte-identical to a fault-free one.
    ``timeout`` (or ``recovery.timeout``) bounds how long a rank waits on
    an empty inbox before reporting a stall.
    """
    cfg, sess = resolve_execution(config, session, stacklevel=3)
    if isinstance(graph, TiledTaskGraph):
        if params is None:
            raise TypeError("params required with a TiledTaskGraph")
        ig = (sess.index_graph(graph, params) if sess is not None
              else graph._index_graph_cfg(params, cfg))
    else:
        ig = graph
    if transport is None:
        transport = "processes" if engine == "numpy" else "inline"
    if transport not in ("inline", "processes"):
        raise ValueError(f"unknown transport {transport!r}")
    if transport == "processes" and engine != "numpy":
        raise ValueError(
            "the device rank engine is level-synchronous and runs on the "
            "inline transport only (jax state does not survive the rank "
            "process boundary); use engine='numpy' across processes")
    faults, policy = cfg.faults, cfg.recovery
    if timeout is None:
        timeout = (policy.timeout if policy is not None
                   and policy.timeout is not None else DEFAULT_STALL_TIMEOUT)
    if ig.n == 0:
        return DistributedRun(ranks=ranks, engine=engine, transport=transport,
                              levels=[], level_of=np.zeros(0, dtype=np.int64))
    slices = partition_graph(ig, ranks)
    attempt = 0
    while True:
        try:
            if transport == "inline":
                engines = _run_inline(slices, engine, faults, attempt,
                                      use_pallas, interpret)
                parts = {e.sl.rank: (e.level, e.stats()) for e in engines}
            else:
                parts = _run_processes(slices, faults, attempt, timeout,
                                       start_method)
            break
        except (StallError, RankFailureError, InjectedRankCrash) as e:
            if transport == "processes" and faults is not None:
                # the worker's plan copy (and its fired log) died with the
                # worker — reconstruct the fires driver-side
                for f in faults.dist_kinds():
                    if attempt < f.times:
                        site = (f.index if f.kind == RANK_CRASH
                                else (f.round, f.index))
                        faults.record(f.kind, site, attempt, e)
            attempt += 1
            if policy is None or attempt > policy.max_retries:
                raise
            time.sleep(policy.base_delay * policy.backoff ** (attempt - 1))
    level_of = np.empty(ig.n, dtype=np.int64)
    stats = []
    for sl in slices:
        lvl, st = parts[sl.rank]
        level_of[sl.lo:sl.hi] = lvl
        stats.append(st)
    return DistributedRun(
        ranks=ranks, engine=engine, transport=transport,
        levels=levels_from_array(level_of), level_of=level_of,
        rank_stats=stats, attempts=attempt)
