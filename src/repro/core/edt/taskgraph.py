"""Polyhedral programs → tiled event-driven task graphs.

A :class:`PolyhedralProgram` is a set of statements (iteration domains) and
dependence polyhedra between them.  :class:`TiledTaskGraph` applies per-
statement tilings, computes the inter-tile dependences with the paper's
compression method (§3, never projection), and exposes the generated-code
primitives of §4:

  * the tile iteration domain per statement (the task creation loop, Fig 3),
  * ``successors`` / ``predecessors`` iterators (the put / get loops, Fig 4),
  * ``pred_count`` — the §4.3 predecessor-count function (autodec init),
  * ``roots`` — the set of tasks without predecessors (master's preschedule
    loop), via destination-projection + subtraction as in §4.3.

Consistency rule (deadlock freedom under over-approximation): the effective
inter-tile dependence is ``Δ_T ∩ (tiledom_src × tiledom_tgt)`` and *all*
generated loops (get / put / count) read the same polyhedron, so a dependence
is counted iff it will be signaled.  Tile-level self-pairs (T,T) of a
statement are excluded everywhere: intra-tile deps are satisfied by sequential
execution inside the task.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional, Sequence

from ..poly import (CountingFunction, LoopNest, Polyhedron, Tiling,
                    make_counting_function, project_onto, tile_dependence,
                    tile_domain)
from ..poly.counting import dims_to_params
from ..poly.scanning import _row_ints

TaskId = tuple[str, tuple[int, ...]]  # (statement name, tile coords)


def _int_rows(poly: Polyhedron) -> tuple[tuple, tuple]:
    """Constraint rows scaled to plain ints (for fast point containment)."""
    return (tuple(_row_ints(r) for r in poly.ineqs),
            tuple(_row_ints(r) for r in poly.eqs))


def _contains_int(ineqs: tuple, eqs: tuple, col: tuple) -> bool:
    """``col`` = (dims..., params..., 1) against pre-scaled integer rows."""
    for r in ineqs:
        if sum(a * b for a, b in zip(r, col)) < 0:
            return False
    for r in eqs:
        if sum(a * b for a, b in zip(r, col)) != 0:
            return False
    return True


@dataclass(frozen=True)
class Statement:
    name: str
    domain: Polyhedron  # iteration domain (params allowed)

    @property
    def ndim(self) -> int:
        return self.domain.ndim


@dataclass(frozen=True)
class Dependence:
    """Pre-tiling dependence polyhedron over (src dims, tgt dims)."""
    src: str
    tgt: str
    delta: Polyhedron  # dims = src.ndim + tgt.ndim
    src_ndim: int
    name: str = ""


@dataclass
class PolyhedralProgram:
    statements: dict[str, Statement] = field(default_factory=dict)
    dependences: list[Dependence] = field(default_factory=list)
    param_names: tuple[str, ...] = ()

    def add_statement(self, name: str, domain: Polyhedron) -> Statement:
        st = Statement(name, domain)
        self.statements[name] = st
        if not self.param_names:
            self.param_names = domain.param_names
        assert domain.param_names == self.param_names, \
            "all statements must share the parameter list"
        return st

    def add_dependence(self, src: str, tgt: str, delta: Polyhedron,
                       name: str = "") -> Dependence:
        s = self.statements[src]
        assert delta.ndim == s.ndim + self.statements[tgt].ndim
        d = Dependence(src, tgt, delta, s.ndim, name or f"{src}->{tgt}")
        self.dependences.append(d)
        return d


@dataclass
class _TiledDep:
    dep: Dependence
    delta_t: Polyhedron          # effective inter-tile dependence
    # successor loop: fix source tile coords (as params) -> iterate targets
    succ_fn: CountingFunction
    # predecessor loop / §4.3 count function: fix target tile -> iterate sources
    pred_fn: CountingFunction
    # delta_t constraint rows as plain ints (fast self-pair containment)
    int_ineqs: tuple = ()
    int_eqs: tuple = ()


class TiledTaskGraph:
    """Tile-level EDT graph with paper-§4 generated-code primitives.

    ``backend`` selects the scanning evaluation path for every generated
    loop (tile nests, get/put loops, counters): ``compiled`` (default,
    integer codegen) or ``fraction`` (the retained reference path) — see
    :mod:`repro.core.poly.scanning`.  Per-``params`` scan state (compiled
    loop bodies, root projections, containment rows) is computed once and
    shared across all tasks, so ``materialize``/``roots``/``pred_count``
    amortize instead of re-deriving per task.
    """

    def __init__(self, program: PolyhedralProgram,
                 tilings: dict[str, Tiling],
                 method: str = "inflate",
                 backend: str = "compiled"):
        self.program = program
        self.tilings = tilings
        self.method = method
        self.backend = backend
        self.param_names = program.param_names

        # Tile iteration domains (task creation loops, Fig 3).
        self.tile_domains: dict[str, Polyhedron] = {}
        self.tile_nests: dict[str, LoopNest] = {}
        for name, st in program.statements.items():
            td = tile_domain(st.domain, tilings[name], method=method)
            self.tile_domains[name] = td
            self.tile_nests[name] = LoopNest(td, backend=backend)

        # Inter-tile dependences by compression (§3), intersected with the
        # product of tile domains for signal/count consistency.
        self.tiled_deps: list[_TiledDep] = []
        self._out: dict[str, list[_TiledDep]] = {n: [] for n in program.statements}
        self._in: dict[str, list[_TiledDep]] = {n: [] for n in program.statements}
        for dep in program.dependences:
            gs = tilings[dep.src]
            gt = tilings[dep.tgt]
            dt = tile_dependence(dep.delta, dep.src_ndim, gs, gt, method=method)
            ns = gs.ndim
            src_td = self.tile_domains[dep.src]
            tgt_td = self.tile_domains[dep.tgt]
            prod = (src_td.add_dims(tgt_td.dim_names)
                    .intersect(tgt_td.add_dims(src_td.dim_names, front=True)
                               .rename(dim_names=src_td.dim_names + tgt_td.dim_names)))
            # align dim names before intersecting
            dt = dt.rename(dim_names=src_td.dim_names + tgt_td.dim_names)
            eff = dt.intersect(prod)
            src_dims = list(range(ns))
            tgt_dims = list(range(ns, eff.ndim))
            ii, ie = _int_rows(eff)
            td = _TiledDep(
                dep=dep,
                delta_t=eff,
                succ_fn=make_counting_function(eff, count_dims=tgt_dims,
                                               fixed_dims=src_dims,
                                               backend=backend),
                pred_fn=make_counting_function(eff, count_dims=src_dims,
                                               fixed_dims=tgt_dims,
                                               backend=backend),
                int_ineqs=ii,
                int_eqs=ie,
            )
            self.tiled_deps.append(td)
            self._out[dep.src].append(td)
            self._in[dep.tgt].append(td)
        # roots_polyhedra() caches (the projections are pure FM work that
        # depends only on the graph, not on params).
        self._roots_projs: Optional[dict[str, list[Polyhedron]]] = None
        self._roots_rows: dict[str, list[tuple[tuple, tuple]]] = {}

    # ------------------------------------------------------------- tasks
    def tasks(self, params: dict[str, int]) -> Iterator[TaskId]:
        """All tasks: the task-creation loops of Fig 3."""
        pv = self._pv(params)
        for name in self.program.statements:
            for t in self.tile_nests[name].iterate(pv):
                yield (name, t)

    def num_tasks(self, params: dict[str, int]) -> int:
        pv = self._pv(params)
        return sum(self.tile_nests[n].count(pv) for n in self.program.statements)

    # -------------------------------------------------- generated loops (§4)
    def successors(self, task: TaskId, params: dict[str, int]) -> Iterator[TaskId]:
        """The put/autodec loop of task: every (dep, tgt) pair, self excluded."""
        name, t = task
        pv = self._pv(params)
        for td in self._out[name]:
            same = td.dep.src == td.dep.tgt
            for tgt in td.succ_fn.points(t, pv):
                if same and tuple(tgt) == tuple(t):
                    continue
                yield (td.dep.tgt, tuple(tgt))

    def predecessors(self, task: TaskId, params: dict[str, int]) -> Iterator[TaskId]:
        """The get loop of the task (Fig 4)."""
        name, t = task
        pv = self._pv(params)
        for td in self._in[name]:
            same = td.dep.src == td.dep.tgt
            for src in td.pred_fn.points(t, pv):
                if same and tuple(src) == tuple(t):
                    continue
                yield (td.dep.src, tuple(src))

    def pred_count(self, task: TaskId, params: dict[str, int]) -> int:
        """§4.3 predecessor-count function (counts (dep, src-tile) pairs)."""
        name, t = task
        return self._pred_count_pv(name, t, self._pv(params))

    def _pred_count_pv(self, name: str, t: tuple, pv: list[int]) -> int:
        """pred_count with a pre-resolved parameter vector (hot path)."""
        total = 0
        for td in self._in[name]:
            c = td.pred_fn(t, pv)
            if td.dep.src == td.dep.tgt and _contains_int(
                    td.int_ineqs, td.int_eqs, tuple(t) + tuple(t) + tuple(pv) + (1,)):
                c -= 1  # exclude the tile-level self pair
            total += c
        return total

    def pred_count_strategies(self) -> dict[str, str]:
        """Which counting form §4.3's heuristic chose, per dependence."""
        return {td.dep.name: td.pred_fn.strategy for td in self.tiled_deps}

    # ------------------------------------------------------------- roots
    def roots_polyhedra(self) -> dict[str, list[Polyhedron]]:
        """§4.3: project each Δ_T onto destination dims (computed once).

        The set of tasks *with* predecessors per statement; roots = tile
        domain minus their union (set difference is evaluated pointwise since
        the difference is generally non-convex).
        """
        if self._roots_projs is not None:
            return self._roots_projs
        out: dict[str, list[Polyhedron]] = {n: [] for n in self.program.statements}
        for td in self.tiled_deps:
            ns = self.tilings[td.dep.src].ndim
            tgt_dims = list(range(ns, td.delta_t.ndim))
            if td.dep.src == td.dep.tgt:
                # self-dependences: a task with only its self-pair is a root;
                # handled pointwise in roots() via pred_count.
                pass
            proj = project_onto(td.delta_t, tgt_dims)
            out[td.dep.tgt].append(proj)
        self._roots_projs = out
        self._roots_rows = {n: [_int_rows(p) for p in projs]
                            for n, projs in out.items()}
        return out

    def roots(self, params: dict[str, int]) -> Iterator[TaskId]:
        """Tasks with no predecessors (the master's scan, made O(1)-startup by
        preschedule in the autodec model)."""
        self.roots_polyhedra()
        pv = self._pv(params)
        tail = tuple(pv) + (1,)
        for name in self.program.statements:
            rows = self._roots_rows[name]
            for t in self.tile_nests[name].iterate(pv):
                col = tuple(t) + tail
                if any(_contains_int(ii, ie, col) for ii, ie in rows):
                    # may still be a root if the only "predecessor" was the
                    # self pair; fall back to the exact count.
                    if self._pred_count_pv(name, t, pv) == 0:
                        yield (name, t)
                else:
                    yield (name, t)

    # ------------------------------------------------------------ materialize
    def materialize(self, params: dict[str, int]) -> "MaterializedGraph":
        """Explicit adjacency (for tests / the prescribed model / wavefronts).

        Batched: the parameter vector, compiled scan functions, and
        per-dependence loop state are resolved once per call, then the put
        loops stream over all tasks of a statement — instead of re-entering
        ``successors`` (and re-binding scan state) per task.  The resulting
        task list, per-task successor order, and pred counts are identical
        to the per-task path.
        """
        pv = self._pv(params)
        tasks: list[TaskId] = []
        by_stmt: dict[str, list[TaskId]] = {}
        for name in self.program.statements:
            ts = [(name, t) for t in self.tile_nests[name].iterate(pv)]
            by_stmt[name] = ts
            tasks.extend(ts)
        succ: dict[TaskId, list[TaskId]] = {t: [] for t in tasks}
        pred_n: dict[TaskId, int] = dict.fromkeys(tasks, 0)
        for name, ts in by_stmt.items():
            for td in self._out[name]:
                tgt_name = td.dep.tgt
                same = td.dep.src == tgt_name
                points = td.succ_fn.points
                for task in ts:
                    t = task[1]
                    out = succ[task]
                    for tgt in points(t, pv):
                        if same and tgt == t:
                            continue
                        s = (tgt_name, tgt)
                        out.append(s)
                        pred_n[s] += 1
        return MaterializedGraph(tasks, succ, pred_n)

    def _pv(self, params: dict[str, int]) -> list[int]:
        return [params[n] for n in self.param_names]


@dataclass
class MaterializedGraph:
    tasks: list[TaskId]
    succ: dict[TaskId, list[TaskId]]
    pred_n: dict[TaskId, int]

    @property
    def n_edges(self) -> int:
        return sum(len(v) for v in self.succ.values())

    def check_acyclic(self) -> bool:
        indeg = dict(self.pred_n)
        ready = [t for t in self.tasks if indeg[t] == 0]
        seen = 0
        while ready:
            t = ready.pop()
            seen += 1
            for s in self.succ[t]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        return seen == len(self.tasks)

    def wavefronts(self) -> list[list[TaskId]]:
        """Earliest-start levels (longest-path depth) — the static schedule."""
        indeg = dict(self.pred_n)
        level = {t: 0 for t in self.tasks}
        cur = [t for t in self.tasks if indeg[t] == 0]
        out: list[list[TaskId]] = []
        while cur:
            out.append(sorted(cur))
            nxt = []
            for t in cur:
                for s in self.succ[t]:
                    indeg[s] -= 1
                    level[s] = max(level[s], level[t] + 1)
                    if indeg[s] == 0:
                        nxt.append(s)
            cur = nxt
        assert sum(len(w) for w in out) == len(self.tasks), "graph has a cycle"
        return out

    def max_ready(self) -> int:
        """r = max tasks simultaneously ready in the greedy wavefront execution."""
        return max((len(w) for w in self.wavefronts()), default=0)

    def max_out_degree(self) -> int:
        return max((len(v) for v in self.succ.values()), default=0)
