"""Polyhedral programs → tiled event-driven task graphs.

A :class:`PolyhedralProgram` is a set of statements (iteration domains) and
dependence polyhedra between them.  :class:`TiledTaskGraph` applies per-
statement tilings, computes the inter-tile dependences with the paper's
compression method (§3, never projection), and exposes the generated-code
primitives of §4:

  * the tile iteration domain per statement (the task creation loop, Fig 3),
  * ``successors`` / ``predecessors`` iterators (the put / get loops, Fig 4),
  * ``pred_count`` — the §4.3 predecessor-count function (autodec init),
  * ``roots`` — the set of tasks without predecessors (master's preschedule
    loop), via destination-projection + subtraction as in §4.3.

Consistency rule (deadlock freedom under over-approximation): the effective
inter-tile dependence is ``Δ_T ∩ (tiledom_src × tiledom_tgt)`` and *all*
generated loops (get / put / count) read the same polyhedron, so a dependence
is counted iff it will be signaled.  Tile-level self-pairs (T,T) of a
statement are excluded everywhere: intra-tile deps are satisfied by sequential
execution inside the task.
"""
from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from ..poly import (CountingFunction, LoopNest, Polyhedron, Tiling,
                    make_counting_function, project_onto, tile_dependence,
                    tile_domain)
from ..poly.scanning import _row_ints
from .config import UNSET, resolve_execution

TaskId = tuple[str, tuple[int, ...]]  # (statement name, tile coords)


def _task_ids(name: str, arr: "np.ndarray") -> list[TaskId]:
    """(name, coords) TaskId tuples for a coord block — C-level zips only."""
    n, d = arr.shape
    if d and n:
        tuples = list(zip(*(arr[:, j].tolist() for j in range(d))))
    else:
        tuples = [()] * n
    return list(zip(itertools.repeat(name), tuples))


def _int_rows(poly: Polyhedron) -> tuple[tuple, tuple]:
    """Constraint rows scaled to plain ints (for fast point containment)."""
    return (tuple(_row_ints(r) for r in poly.ineqs),
            tuple(_row_ints(r) for r in poly.eqs))


def _coord_keys(arr: "np.ndarray"):
    """Mixed-radix keys over the block's bounding box: (keys, mins, strides).

    Lexicographic row order makes the keys strictly increasing, so they
    index the block via searchsorted — or directly, when the block fills
    its bounding box (see :func:`_map_local`).
    """
    n, d = arr.shape
    if n and d:
        mins = arr.min(axis=0)
        extents = arr.max(axis=0) - mins + 1
        strides = np.ones(d, dtype=np.int64)
        for j in range(d - 2, -1, -1):
            strides[j] = strides[j + 1] * extents[j + 1]
        keys = (arr - mins) @ strides
    else:
        mins = np.zeros(d, dtype=np.int64)
        strides = np.zeros(d, dtype=np.int64)
        keys = np.zeros(n, dtype=np.int64)
    return keys, mins, strides


def _map_local(keys: "np.ndarray", mins, strides,
               coords: "np.ndarray") -> "np.ndarray":
    """Coordinate rows -> local task indices within one statement block.

    Dense fast path: strictly-increasing keys starting at 0 and ending at
    n-1 must be exactly ``arange(n)`` (mixed-radix keys are injective), so
    the key *is* the index and the searchsorted disappears — boxes, i.e.
    the million-task scaling cases, never pay the log-factor.
    """
    k = (coords - mins) @ strides
    n = keys.shape[0]
    if n and keys[0] == 0 and int(keys[-1]) == n - 1:
        return k
    return np.searchsorted(keys, k)


def _contains_int(ineqs: tuple, eqs: tuple, col: tuple) -> bool:
    """``col`` = (dims..., params..., 1) against pre-scaled integer rows."""
    for r in ineqs:
        if sum(a * b for a, b in zip(r, col)) < 0:
            return False
    for r in eqs:
        if sum(a * b for a, b in zip(r, col)) != 0:
            return False
    return True


@dataclass(frozen=True)
class Statement:
    name: str
    domain: Polyhedron  # iteration domain (params allowed)

    @property
    def ndim(self) -> int:
        return self.domain.ndim


@dataclass(frozen=True)
class Dependence:
    """Pre-tiling dependence polyhedron over (src dims, tgt dims)."""
    src: str
    tgt: str
    delta: Polyhedron  # dims = src.ndim + tgt.ndim
    src_ndim: int
    name: str = ""


@dataclass
class PolyhedralProgram:
    statements: dict[str, Statement] = field(default_factory=dict)
    dependences: list[Dependence] = field(default_factory=list)
    param_names: tuple[str, ...] = ()
    # registry name (``repro.core.programs.PROGRAMS`` key) — lets consumers
    # that attach semantics to a program (the fused executor's stencil
    # bodies) find it without threading the name separately
    name: str = ""

    def add_statement(self, name: str, domain: Polyhedron) -> Statement:
        st = Statement(name, domain)
        self.statements[name] = st
        if not self.param_names:
            self.param_names = domain.param_names
        assert domain.param_names == self.param_names, (
            "all statements must share the parameter list")
        return st

    def add_dependence(self, src: str, tgt: str, delta: Polyhedron,
                       name: str = "") -> Dependence:
        s = self.statements[src]
        assert delta.ndim == s.ndim + self.statements[tgt].ndim
        d = Dependence(src, tgt, delta, s.ndim, name or f"{src}->{tgt}")
        self.dependences.append(d)
        return d


@dataclass
class _TiledDep:
    dep: Dependence
    delta_t: Polyhedron          # effective inter-tile dependence
    # successor loop: fix source tile coords (as params) -> iterate targets
    succ_fn: CountingFunction
    # predecessor loop / §4.3 count function: fix target tile -> iterate sources
    pred_fn: CountingFunction
    # delta_t constraint rows as plain ints (fast self-pair containment)
    int_ineqs: tuple = ()
    int_eqs: tuple = ()
    # lazy joint nest over (src dims, tgt dims): one vectorized scan of this
    # polyhedron yields every edge of the dependence (numpy backend)
    joint_nest: Optional[LoopNest] = None
    # position in TiledTaskGraph.tiled_deps — the shard planner's unit key
    idx: int = -1


class TiledTaskGraph:
    """Tile-level EDT graph with paper-§4 generated-code primitives.

    ``backend`` selects the scanning evaluation path for every generated
    loop (tile nests, get/put loops, counters): ``compiled`` (default,
    integer codegen), ``numpy`` (vectorized batch enumeration) or
    ``fraction`` (the retained reference path) — see
    :mod:`repro.core.poly.scanning`.  Per-``params`` scan state (compiled
    loop bodies, root projections, containment rows) is computed once and
    shared across all tasks, so ``materialize``/``roots``/``pred_count``
    amortize instead of re-deriving per task.

    With ``backend="numpy"`` the batch layer replaces per-task dispatch
    entirely: tile domains are enumerated as ``(N, ndim)`` index arrays,
    every dependence's edges come from **one** vectorized scan of its joint
    ``Δ_T`` polyhedron (src dims × tgt dims — lexicographic order groups
    the put loops by source task for free), predecessor counts evaluate as
    matrix products over tile blocks, and ``roots``/``materialize``/
    ``index_graph`` consume whole statements per call.  Results are
    byte-identical to the scalar backends (asserted by the equivalence
    suite and the taskgen benchmark).
    """

    def __init__(self, program: PolyhedralProgram,
                 tilings: dict[str, Tiling],
                 method: str = "inflate",
                 backend: str = "compiled"):
        self.program = program
        self.tilings = tilings
        self.method = method
        self.backend = backend
        self.param_names = program.param_names

        # Tile iteration domains (task creation loops, Fig 3).
        self.tile_domains: dict[str, Polyhedron] = {}
        self.tile_nests: dict[str, LoopNest] = {}
        for name, st in program.statements.items():
            td = tile_domain(st.domain, tilings[name], method=method)
            self.tile_domains[name] = td
            self.tile_nests[name] = LoopNest(td, backend=backend)

        # Inter-tile dependences by compression (§3), intersected with the
        # product of tile domains for signal/count consistency.
        self.tiled_deps: list[_TiledDep] = []
        self._out: dict[str, list[_TiledDep]] = {n: [] for n in program.statements}
        self._in: dict[str, list[_TiledDep]] = {n: [] for n in program.statements}
        for dep in program.dependences:
            gs = tilings[dep.src]
            gt = tilings[dep.tgt]
            dt = tile_dependence(dep.delta, dep.src_ndim, gs, gt, method=method)
            ns = gs.ndim
            src_td = self.tile_domains[dep.src]
            tgt_td = self.tile_domains[dep.tgt]
            prod = (src_td.add_dims(tgt_td.dim_names)
                    .intersect(tgt_td.add_dims(src_td.dim_names, front=True)
                               .rename(dim_names=src_td.dim_names + tgt_td.dim_names)))
            # align dim names before intersecting
            dt = dt.rename(dim_names=src_td.dim_names + tgt_td.dim_names)
            eff = dt.intersect(prod)
            src_dims = list(range(ns))
            tgt_dims = list(range(ns, eff.ndim))
            ii, ie = _int_rows(eff)
            td = _TiledDep(
                dep=dep,
                delta_t=eff,
                succ_fn=make_counting_function(eff, count_dims=tgt_dims,
                                               fixed_dims=src_dims,
                                               backend=backend),
                pred_fn=make_counting_function(eff, count_dims=src_dims,
                                               fixed_dims=tgt_dims,
                                               backend=backend),
                int_ineqs=ii,
                int_eqs=ie,
                idx=len(self.tiled_deps),
            )
            self.tiled_deps.append(td)
            self._out[dep.src].append(td)
            self._in[dep.tgt].append(td)
        # roots_polyhedra() caches (the projections are pure FM work that
        # depends only on the graph, not on params).
        self._roots_projs: Optional[dict[str, list[Polyhedron]]] = None
        self._roots_rows: dict[str, list[tuple[tuple, tuple]]] = {}
        # driver-side restricted nests for sharded block counting
        # ((kind, key) -> (nest, diag nest); see repro.core.edt.shard)
        self._shard_nests: dict = {}
        self._fingerprint: Optional[str] = None

    # ------------------------------------------------------------- tasks
    def tasks(self, params: dict[str, int]) -> Iterator[TaskId]:
        """All tasks: the task-creation loops of Fig 3."""
        pv = self._pv(params)
        for name in self.program.statements:
            for t in self.tile_nests[name].iterate(pv):
                yield (name, t)

    def num_tasks(self, params: dict[str, int]) -> int:
        pv = self._pv(params)
        return sum(self.tile_nests[n].count(pv) for n in self.program.statements)

    # -------------------------------------------------- generated loops (§4)
    def successors(self, task: TaskId, params: dict[str, int]) -> Iterator[TaskId]:
        """The put/autodec loop of task: every (dep, tgt) pair, self excluded."""
        name, t = task
        pv = self._pv(params)
        for td in self._out[name]:
            same = td.dep.src == td.dep.tgt
            for tgt in td.succ_fn.points(t, pv):
                if same and tuple(tgt) == tuple(t):
                    continue
                yield (td.dep.tgt, tuple(tgt))

    def predecessors(self, task: TaskId, params: dict[str, int]) -> Iterator[TaskId]:
        """The get loop of the task (Fig 4)."""
        name, t = task
        pv = self._pv(params)
        for td in self._in[name]:
            same = td.dep.src == td.dep.tgt
            for src in td.pred_fn.points(t, pv):
                if same and tuple(src) == tuple(t):
                    continue
                yield (td.dep.src, tuple(src))

    def pred_count(self, task: TaskId, params: dict[str, int]) -> int:
        """§4.3 predecessor-count function (counts (dep, src-tile) pairs)."""
        name, t = task
        return self._pred_count_pv(name, t, self._pv(params))

    def _pred_count_pv(self, name: str, t: tuple, pv: list[int]) -> int:
        """pred_count with a pre-resolved parameter vector (hot path)."""
        total = 0
        for td in self._in[name]:
            c = td.pred_fn(t, pv)
            if td.dep.src == td.dep.tgt and _contains_int(
                    td.int_ineqs, td.int_eqs, tuple(t) + tuple(t) + tuple(pv) + (1,)):
                c -= 1  # exclude the tile-level self pair
            total += c
        return total

    def pred_count_strategies(self) -> dict[str, str]:
        """Which counting form §4.3's heuristic chose, per dependence."""
        return {td.dep.name: td.pred_fn.strategy for td in self.tiled_deps}

    # ------------------------------------------------------------- roots
    def roots_polyhedra(self) -> dict[str, list[Polyhedron]]:
        """§4.3: project each Δ_T onto destination dims (computed once).

        The set of tasks *with* predecessors per statement; roots = tile
        domain minus their union (set difference is evaluated pointwise since
        the difference is generally non-convex).
        """
        if self._roots_projs is not None:
            return self._roots_projs
        out: dict[str, list[Polyhedron]] = {n: [] for n in self.program.statements}
        for td in self.tiled_deps:
            ns = self.tilings[td.dep.src].ndim
            tgt_dims = list(range(ns, td.delta_t.ndim))
            if td.dep.src == td.dep.tgt:
                # self-dependences: a task with only its self-pair is a root;
                # handled pointwise in roots() via pred_count.
                pass
            proj = project_onto(td.delta_t, tgt_dims)
            out[td.dep.tgt].append(proj)
        self._roots_projs = out
        self._roots_rows = {n: [_int_rows(p) for p in projs]
                            for n, projs in out.items()}
        return out

    def roots(self, params: dict[str, int], shards=UNSET, parallel=UNSET,
              pool=UNSET, faults=UNSET, recovery=UNSET, *,
              config=None, session=None) -> Iterator[TaskId]:
        """Tasks with no predecessors (the master's scan, made O(1)-startup by
        preschedule in the autodec model).

        Execution knobs arrive via ``config=`` (an
        :class:`~repro.core.edt.config.ExecutionConfig`) or ``session=``;
        the per-call kwargs are a deprecated spelling of the same config.
        Sharded runs derive the root set from the merged index graph
        (``pred_n == 0`` per statement block) — same tasks, same order as
        the in-process scans — and, unlike the pre-config signature (which
        dropped them), ``faults``/``recovery`` reach those scans too.
        """
        cfg, sess = resolve_execution(
            config, session, stacklevel=3,
            legacy=dict(shards=shards, parallel=parallel, pool=pool,
                        faults=faults, recovery=recovery))
        if sess is not None:
            return sess.roots(self, params)
        return self._roots_cfg(params, cfg)

    def _roots_cfg(self, params: dict[str, int], cfg) -> Iterator[TaskId]:
        if cfg.resolve_shards() > 1:
            return self._roots_indexed(self._index_graph_cfg(params, cfg))
        pv = self._pv(params)
        if self.backend == "numpy":
            return self._roots_numpy(pv)
        return self._roots_scalar(pv)

    def _roots_indexed(self, ig: "IndexedGraph") -> Iterator[TaskId]:
        """Zero in-degree tasks straight from merged index arrays."""
        off = 0
        for name, arr in ig.stmt_blocks:
            n = arr.shape[0]
            idx = np.flatnonzero(ig.pred_n[off:off + n] == 0)
            if idx.size:
                rows = arr[idx].tolist()
                for r in rows:
                    yield (name, tuple(r))
            off += n

    def _roots_scalar(self, pv: list[int]) -> Iterator[TaskId]:
        self.roots_polyhedra()
        tail = tuple(pv) + (1,)
        for name in self.program.statements:
            rows = self._roots_rows[name]
            for t in self.tile_nests[name].iterate(pv):
                col = tuple(t) + tail
                if any(_contains_int(ii, ie, col) for ii, ie in rows):
                    # may still be a root if the only "predecessor" was the
                    # self pair; fall back to the exact count.
                    if self._pred_count_pv(name, t, pv) == 0:
                        yield (name, t)
                else:
                    yield (name, t)

    def _roots_numpy(self, pv: list[int]) -> Iterator[TaskId]:
        """Whole-statement root scan: one pred-count block per statement."""
        for name in self.program.statements:
            tiles = self.tile_nests[name].iterate_array(pv)
            counts = self._pred_counts_array(name, tiles, pv)
            rows = tiles.tolist()
            for i in np.flatnonzero(counts == 0).tolist():
                yield (name, tuple(rows[i]))

    # ------------------------------------------------------ batched (numpy)
    def tasks_arrays(self, params: dict[str, int]) -> dict[str, "np.ndarray"]:
        """Per-statement tile coordinates as ``(N, ndim)`` int64 arrays."""
        pv = self._pv(params)
        return {name: self.tile_nests[name].iterate_array(pv)
                for name in self.program.statements}

    def pred_count_block(self, name: str, tiles,
                         params: dict[str, int]) -> "np.ndarray":
        """§4.3 predecessor counts for a whole block of target tiles.

        Equals ``[pred_count((name, t), params) for t in tiles]`` but the
        enumerator-form counters evaluate as array arithmetic over the
        block, and the self-pair exclusion is one containment mask.
        """
        return self._pred_counts_array(
            name, np.asarray(tiles, dtype=np.int64), self._pv(params))

    def _pred_counts_array(self, name: str, tiles: "np.ndarray",
                           pv: list[int]) -> "np.ndarray":
        total = np.zeros(tiles.shape[0], dtype=np.int64)
        for td in self._in[name]:
            total += td.pred_fn.count_block(tiles, pv)
            if td.dep.src == td.dep.tgt:
                total -= self._self_pair_mask(td, tiles, pv)
        return total

    def _self_pair_mask(self, td: _TiledDep, tiles: "np.ndarray",
                        pv: list[int]) -> "np.ndarray":
        """1 where the tile-level self pair (T, T) lies in Δ_T, else 0."""
        n, ns = tiles.shape
        mask = np.ones(n, dtype=bool)
        for rows, eq in ((td.int_ineqs, False), (td.int_eqs, True)):
            for r in rows:
                coeff = np.asarray(
                    [r[j] + r[ns + j] for j in range(ns)], dtype=np.int64)
                c = r[-1] + sum(a * p for a, p in zip(r[2 * ns:-1], pv))
                v = tiles @ coeff + c
                mask &= (v == 0) if eq else (v >= 0)
        return mask.astype(np.int64)

    def _joint_nest(self, td: _TiledDep) -> LoopNest:
        """Lazy loop nest over the joint (src, tgt) dependence polyhedron."""
        if td.joint_nest is None:
            td.joint_nest = LoopNest(td.delta_t)
        return td.joint_nest

    def _stmt_index(self, pv: list[int], with_tasks: bool = True,
                    tiles: Optional[dict] = None) -> dict:
        """Per statement: coord array, ravel-key index, optional TaskIds.

        Tile coordinates are encoded into mixed-radix keys over the
        statement's bounding box; lexicographic task order makes the keys
        sorted, so edge endpoints map to task indices via searchsorted —
        no per-task hashing anywhere in the batch paths.  TaskId tuples
        (the scalar-world labels) are only built when asked for: the pure
        array paths (``index_graph``) never pay the per-task tuple cost.
        ``tiles`` injects pre-scanned coordinate blocks (the sharded merge
        path) in place of in-process enumeration.
        """
        info = {}
        for name in self.program.statements:
            arr = (tiles[name] if tiles is not None
                   else self.tile_nests[name].iterate_array(pv))
            ts = _task_ids(name, arr) if with_tasks else None
            keys, mins, strides = _coord_keys(arr)
            info[name] = (ts, keys, mins, strides, arr)
        return info

    def _dep_edges(self, td: _TiledDep, pv: list[int],
                   raw: Optional["np.ndarray"] = None) -> "np.ndarray":
        """All (src tile, tgt tile) edge rows of one dependence, self pairs
        excluded — a single vectorized scan of the joint polyhedron, or the
        merged per-shard blocks of that same scan (``raw``)."""
        edges = raw if raw is not None else self._joint_nest(td).iterate_array(pv)
        ns = self.tilings[td.dep.src].ndim
        if td.dep.src == td.dep.tgt and edges.shape[0]:
            keep = (edges[:, :ns] != edges[:, ns:]).any(axis=1)
            edges = edges[keep]
        return edges

    def _stmt_bases(self, info) -> dict[str, int]:
        """Global id of each statement's first task (program order)."""
        base: dict[str, int] = {}
        n = 0
        for name in self.program.statements:
            base[name] = n
            n += info[name][4].shape[0]
        return base

    def _edge_indices(self, td: _TiledDep, pv: list[int], info, scans,
                      base: dict[str, int], global_ids: bool = False):
        """One dependence's edges as (src, tgt) task-index columns.

        Self pairs are dropped.  Worker-mapped sharded scans pass through
        untouched (they are already global ids); raw rows — single-process
        or sharded-raw — map through :func:`_map_local`.
        """
        sname, tname = td.dep.src, td.dep.tgt
        if scans is not None and td.idx in scans.edges_idx:
            gsrc, gtgt = scans.edges_idx[td.idx]
            if global_ids:
                return gsrc, gtgt
            return gsrc - base[sname], gtgt - base[tname]
        edges = self._dep_edges(
            td, pv,
            raw=scans.edges_raw.get(td.idx) if scans is not None else None)
        if not edges.shape[0]:
            z = np.zeros(0, dtype=np.int64)
            return z, z
        ns = self.tilings[sname].ndim
        _, keys_s, mins_s, strides_s, _ = info[sname]
        _, keys_t, mins_t, strides_t, _ = info[tname]
        src_idx = _map_local(keys_s, mins_s, strides_s, edges[:, :ns])
        tgt_idx = _map_local(keys_t, mins_t, strides_t, edges[:, ns:])
        if global_ids:
            return src_idx + base[sname], tgt_idx + base[tname]
        return src_idx, tgt_idx

    def _materialize_numpy(self, pv: list[int],
                           scans=None) -> "MaterializedGraph":
        info = self._stmt_index(
            pv, tiles=scans.tiles if scans is not None else None)
        base = self._stmt_bases(info)
        tasks: list[TaskId] = []
        succ: dict[TaskId, list[TaskId]] = {}
        stmt_succ: dict[str, list[list[TaskId]]] = {}
        pred_counts: dict[str, np.ndarray] = {}
        for name in self.program.statements:
            ts = info[name][0]
            tasks.extend(ts)
            lists: list[list[TaskId]] = [[] for _ in ts]
            stmt_succ[name] = lists
            succ.update(zip(ts, lists))
            pred_counts[name] = np.zeros(len(ts), dtype=np.int64)
        for name in self.program.statements:
            for td in self._out[name]:
                tgt_name = td.dep.tgt
                src_idx, tgt_idx = self._edge_indices(td, pv, info, scans, base)
                ne = src_idx.shape[0]
                if not ne:
                    continue
                ts_t = info[tgt_name][0]
                pred_counts[tgt_name] += np.bincount(
                    tgt_idx, minlength=len(ts_t))
                tg = _task_ids(tgt_name, info[tgt_name][4][tgt_idx])
                # edges are lex-sorted by source: group bounds are where the
                # source index changes, then one list-extend per source task
                starts = np.flatnonzero(
                    np.r_[True, src_idx[1:] != src_idx[:-1]])
                bounds = np.append(starts, ne).tolist()
                owners = src_idx[starts].tolist()
                lists = stmt_succ[name]
                for gi, u in enumerate(owners):
                    lists[u].extend(tg[bounds[gi]:bounds[gi + 1]])
        pred_n: dict[TaskId, int] = {}
        for name in self.program.statements:
            pred_n.update(zip(info[name][0], pred_counts[name].tolist()))
        return MaterializedGraph(tasks, succ, pred_n)

    def _resolve_shards(self, shards: Optional[int], parallel) -> int:
        """``shards=``/``parallel=`` -> effective shard count (0 = in-process).

        ``parallel=True`` is the convenience spelling for one shard per
        available core; an explicit ``shards=`` always wins.
        """
        if shards is None and parallel:
            return os.cpu_count() or 1
        return int(shards or 0)

    def _sharded_scans(self, params: dict[str, int], shards: int,
                       pool=None, faults=None, recovery=None) -> dict:
        from .shard import scan_sharded  # local import: avoid cycle
        return scan_sharded(self, params, shards, pool=pool,
                            faults=faults, recovery=recovery)

    def index_graph(self, params: dict[str, int], shards=UNSET,
                    parallel=UNSET, pool=UNSET, faults=UNSET, recovery=UNSET,
                    *, config=None, session=None) -> "IndexedGraph":
        """The whole task graph as flat index arrays (no per-task tuples).

        The numpy backend's native graph product: tasks are global integer
        ids (statement blocks concatenated in program order, lex order
        within — same total order as ``materialize().tasks``), edges are
        two parallel int arrays, and ``pred_n`` is their bincount.  Pure
        array output: TaskId labels are derived lazily on access, so
        generation itself never touches per-task Python objects.

        Execution knobs arrive via ``config=`` (an
        :class:`~repro.core.edt.config.ExecutionConfig`: shard fan-out,
        pool reuse, fault injection, retry policy — see
        :mod:`.shard` / ``docs/robustness.md``) or ``session=`` (cached by
        ``(fingerprint, params)`` in the session's
        :class:`~repro.core.edt.cache.GraphCache`).  The per-call
        ``shards=``/``parallel=``/``pool=``/``faults=``/``recovery=``
        kwargs are the deprecated spelling of the same config.
        """
        cfg, sess = resolve_execution(
            config, session, stacklevel=3,
            legacy=dict(shards=shards, parallel=parallel, pool=pool,
                        faults=faults, recovery=recovery))
        if sess is not None:
            return sess.index_graph(self, params)
        return self._index_graph_cfg(params, cfg)

    def _index_graph_cfg(self, params: dict[str, int], cfg,
                         scans=None) -> "IndexedGraph":
        """``index_graph`` body under a resolved config.

        ``scans`` injects pre-merged scan products (a
        :class:`~repro.core.edt.shard.ShardedScans`) in place of both the
        in-process and the sharded scans — the graph cache's incremental
        re-materialization hands stitched blocks through here.
        """
        pv = self._pv(params)
        n_shards = cfg.resolve_shards()
        if scans is None and n_shards > 1:
            scans = self._sharded_scans(params, n_shards, pool=cfg.pool,
                                        faults=cfg.faults,
                                        recovery=cfg.recovery)
        info = self._stmt_index(
            pv, with_tasks=False,
            tiles=scans.tiles if scans is not None else None)
        base = self._stmt_bases(info)
        blocks = [(name, info[name][4]) for name in self.program.statements]
        n = sum(arr.shape[0] for _, arr in blocks)
        srcs, tgts = [], []
        spans: dict[int, tuple[int, int]] = {}
        off = 0
        for name in self.program.statements:
            for td in self._out[name]:
                gsrc, gtgt = self._edge_indices(td, pv, info, scans, base,
                                                global_ids=True)
                ne = int(gsrc.shape[0])
                spans[td.idx] = (off, off + ne)
                off += ne
                if ne:
                    srcs.append(gsrc)
                    tgts.append(gtgt)
        z = np.zeros(0, dtype=np.int64)
        edge_src = np.concatenate(srcs) if srcs else z
        edge_tgt = np.concatenate(tgts) if tgts else z
        return IndexedGraph(
            stmt_blocks=blocks, n=n, edge_src=edge_src, edge_tgt=edge_tgt,
            pred_n=np.bincount(edge_tgt, minlength=n), dep_spans=spans)

    # ------------------------------------------------------------ materialize
    def materialize(self, params: dict[str, int], shards=UNSET,
                    parallel=UNSET, pool=UNSET, faults=UNSET, recovery=UNSET,
                    *, config=None, session=None) -> "MaterializedGraph":
        """Explicit adjacency (for tests / the prescribed model / wavefronts).

        Batched: the parameter vector, compiled scan functions, and
        per-dependence loop state are resolved once per call, then the put
        loops stream over all tasks of a statement — instead of re-entering
        ``successors`` (and re-binding scan state) per task.  The resulting
        task list, per-task successor order, and pred counts are identical
        to the per-task path.  The ``numpy`` backend goes further: each
        dependence's edge list is one vectorized scan of the joint Δ_T
        polyhedron (see ``_materialize_numpy``).

        Execution knobs arrive via ``config=``/``session=``; the per-call
        kwargs are the deprecated spelling.  Sharded configs run the scans
        on a process pool (:mod:`.shard`) and merge the blocks — identical
        graph, any backend.  Callers that only need arrays should prefer
        :meth:`index_graph`, which never builds the per-task dicts.
        """
        cfg, sess = resolve_execution(
            config, session, stacklevel=3,
            legacy=dict(shards=shards, parallel=parallel, pool=pool,
                        faults=faults, recovery=recovery))
        if sess is not None:
            return sess.materialize(self, params)
        return self._materialize_cfg(params, cfg)

    def _materialize_cfg(self, params: dict[str, int],
                         cfg) -> "MaterializedGraph":
        pv = self._pv(params)
        n_shards = cfg.resolve_shards()
        if n_shards > 1:
            return self._materialize_numpy(
                pv, scans=self._sharded_scans(params, n_shards,
                                              pool=cfg.pool,
                                              faults=cfg.faults,
                                              recovery=cfg.recovery))
        if self.backend == "numpy":
            return self._materialize_numpy(pv)
        tasks: list[TaskId] = []
        by_stmt: dict[str, list[TaskId]] = {}
        for name in self.program.statements:
            ts = [(name, t) for t in self.tile_nests[name].iterate(pv)]
            by_stmt[name] = ts
            tasks.extend(ts)
        succ: dict[TaskId, list[TaskId]] = {t: [] for t in tasks}
        pred_n: dict[TaskId, int] = dict.fromkeys(tasks, 0)
        for name, ts in by_stmt.items():
            for td in self._out[name]:
                tgt_name = td.dep.tgt
                same = td.dep.src == tgt_name
                points = td.succ_fn.points
                for task in ts:
                    t = task[1]
                    out = succ[task]
                    for tgt in points(t, pv):
                        if same and tgt == t:
                            continue
                        s = (tgt_name, tgt)
                        out.append(s)
                        pred_n[s] += 1
        return MaterializedGraph(tasks, succ, pred_n)

    # ------------------------------------------------------------- identity
    def fingerprint(self) -> str:
        """Canonical parametric-program fingerprint (sha256 hex digest).

        Hashes the canonicalized tile domains and effective inter-tile
        dependence polyhedra (plus tilings, tiling method, and parameter
        list) — everything that determines the generated graph and nothing
        that doesn't.  The scanning ``backend`` is deliberately excluded:
        all backends produce byte-identical graphs (the equivalence suite's
        invariant), so cache entries keyed by this fingerprint are shared
        across backends and across graph instances rebuilt from the same
        program.
        """
        if self._fingerprint is None:
            import hashlib
            parts = [repr(self.param_names), self.method]
            for name in self.program.statements:
                p = self.tile_domains[name].canonical()
                parts.append(repr((name, self.tilings[name].sizes,
                                   p.ineqs, p.eqs)))
            for td in self.tiled_deps:
                p = td.delta_t.canonical()
                parts.append(repr((td.dep.src, td.dep.tgt, p.ineqs, p.eqs)))
            self._fingerprint = hashlib.sha256(
                "\n".join(parts).encode()).hexdigest()
        return self._fingerprint

    def scan_units(self) -> list[tuple[str, object, LoopNest]]:
        """Every scan unit behind ``index_graph``: ``(kind, key, nest)``.

        Statement tile domains come first (``kind = shard.TILES``, keyed by
        statement name), then the joint dependence polyhedra
        (``kind = shard.EDGES``, keyed by ``tiled_deps`` index) — the same
        unit decomposition the shard planner partitions, reused by the
        graph cache to decide per-unit outer-param reuse
        (:meth:`LoopNest.outer_only_params`).
        """
        from .shard import EDGES, TILES  # local import: avoid cycle
        units: list[tuple[str, object, LoopNest]] = []
        for name in self.program.statements:
            units.append((TILES, name, self.tile_nests[name]))
        for td in self.tiled_deps:
            units.append((EDGES, td.idx, self._joint_nest(td)))
        return units

    def _pv(self, params: dict[str, int]) -> list[int]:
        return [params[n] for n in self.param_names]


@dataclass
class IndexedGraph:
    """Flat-array task graph: global task ids + parallel edge arrays.

    ``tasks`` (TaskId labels) is derived lazily — consumers that stay in
    index space (wavefront leveling, batch executors) never build it.
    """
    stmt_blocks: list[tuple[str, "np.ndarray"]]  # (statement, (N, d) coords)
    n: int
    # int64 global task indices; sorted by source only WITHIN each
    # dependence's block (blocks are concatenated per statement × dep) —
    # CSR consumers must sort/argsort globally first.
    edge_src: "np.ndarray"
    edge_tgt: "np.ndarray"
    pred_n: "np.ndarray"    # int64 in-degrees, indexed by global task id
    # per-dependence [start, stop) slice of the edge arrays, keyed by
    # tiled_deps index (deps are concatenated in statement × out-dep order).
    # Lets the graph cache reconstruct a dependence's raw joint rows without
    # storing them; absent on hand-built graphs.
    dep_spans: Optional[dict[int, tuple[int, int]]] = None
    _tasks: Optional[list[TaskId]] = None

    @property
    def tasks(self) -> list[TaskId]:
        if self._tasks is None:
            out: list[TaskId] = []
            for name, arr in self.stmt_blocks:
                out.extend(_task_ids(name, arr))
            self._tasks = out
        return self._tasks

    @property
    def n_edges(self) -> int:
        return int(self.edge_src.shape[0])

    @property
    def nbytes(self) -> int:
        """Array payload size (the graph cache's byte-budget unit)."""
        b = self.edge_src.nbytes + self.edge_tgt.nbytes + self.pred_n.nbytes
        for _, arr in self.stmt_blocks:
            b += arr.nbytes
        return int(b)


@dataclass
class MaterializedGraph:
    tasks: list[TaskId]
    succ: dict[TaskId, list[TaskId]]
    pred_n: dict[TaskId, int]

    @property
    def n_edges(self) -> int:
        return sum(len(v) for v in self.succ.values())

    def check_acyclic(self) -> bool:
        indeg = dict(self.pred_n)
        ready = [t for t in self.tasks if indeg[t] == 0]
        seen = 0
        while ready:
            t = ready.pop()
            seen += 1
            for s in self.succ[t]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        return seen == len(self.tasks)

    def wavefronts(self) -> list[list[TaskId]]:
        """Earliest-start levels (longest-path depth) — the static schedule."""
        indeg = dict(self.pred_n)
        level = {t: 0 for t in self.tasks}
        cur = [t for t in self.tasks if indeg[t] == 0]
        out: list[list[TaskId]] = []
        while cur:
            out.append(sorted(cur))
            nxt = []
            for t in cur:
                for s in self.succ[t]:
                    indeg[s] -= 1
                    level[s] = max(level[s], level[t] + 1)
                    if indeg[s] == 0:
                        nxt.append(s)
            cur = nxt
        assert sum(len(w) for w in out) == len(self.tasks), "graph has a cycle"
        return out

    def max_ready(self) -> int:
        """r = max tasks simultaneously ready in the greedy wavefront execution."""
        return max((len(w) for w in self.wavefronts()), default=0)

    def max_out_degree(self) -> int:
        return max((len(v) for v in self.succ.values()), default=0)
