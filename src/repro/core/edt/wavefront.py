"""Static wavefront schedule synthesis — the TPU-side realization of EDT.

XLA programs cannot spawn tasks dynamically, so on-device we resolve the
autodec counters *at compile time*: every task's earliest start level
(longest-path depth in the tile graph) becomes its wavefront index, and the
whole graph lowers to a loop over wavefronts in which all tasks of a level run
in parallel (data parallel across tiles / pipeline stages).  This is the
"overhead → 0" limit of the paper's Table 2: zero runtime sync objects,
because the dependence relation was exact at compile time.

For uniform dependences (constant distance vectors — pipelines, stencils) the
wavefront index also has a closed affine form; we derive it when possible so
huge tile spaces never need materializing.

With ``backend="numpy"`` graphs, :func:`synthesize` levels the graph from
flat index arrays (:meth:`TiledTaskGraph.index_graph`): a CSR Kahn sweep
where each wavefront's out-edges are gathered, decremented, and
max-propagated as whole arrays — no per-task Python dispatch.  The executor
consumes the resulting levels as batches (:func:`simulate_schedule` /
``Sim.make_ready_batch``).
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Optional

import numpy as np

from .config import UNSET, resolve_execution
from .executor import Sim
from .taskgraph import IndexedGraph, TaskId, TiledTaskGraph


@dataclass
class WavefrontSchedule:
    levels: list[list[TaskId]]
    level_of: dict[TaskId, int]

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def max_width(self) -> int:
        return max((len(lv) for lv in self.levels), default=0)

    def stats(self) -> dict:
        n = sum(len(lv) for lv in self.levels)
        return {"tasks": n, "depth": self.depth, "max_width": self.max_width,
                "avg_width": n / max(1, self.depth)}


@dataclass
class IndexedSchedule:
    """Wavefront levels in pure index space: arrays of global task ids.

    The million-task representation — no TaskId tuples, no dicts; levels
    feed the executor straight from the merged arrays
    (:func:`simulate_indexed` / :meth:`Sim.make_ready_ids`).  Ids within a
    level ascend, so iteration order is deterministic.
    """
    levels: list["np.ndarray"]
    level_of: "np.ndarray"   # level index per global task id

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def max_width(self) -> int:
        return max((int(lv.size) for lv in self.levels), default=0)

    def stats(self) -> dict:
        n = int(self.level_of.shape[0])
        return {"tasks": n, "depth": self.depth, "max_width": self.max_width,
                "avg_width": n / max(1, self.depth)}


def synthesize(graph: TiledTaskGraph, params: dict, shards=UNSET,
               parallel=UNSET, pool=UNSET, faults=UNSET, recovery=UNSET, *,
               config=None, session=None) -> WavefrontSchedule:
    """Longest-path leveling of the tile graph.

    ``numpy``-backend graphs level from flat index arrays (whole wavefronts
    per step); the scalar path materializes and walks the dict graph.  Both
    produce identical schedules.  Execution knobs arrive via
    ``config=``/``session=`` (the per-call kwargs are the deprecated
    spelling); sharded configs fan the underlying scans across processes
    (any backend) — the schedule is unchanged, only generation
    parallelizes.
    """
    cfg, sess = resolve_execution(
        config, session, stacklevel=3,
        legacy=dict(shards=shards, parallel=parallel, pool=pool,
                    faults=faults, recovery=recovery))
    if sess is not None:
        return sess.synthesize(graph, params)
    if cfg.resolve_shards() > 1 or graph.backend == "numpy":
        return _synthesize_from_ig(graph._index_graph_cfg(params, cfg))
    g = graph._materialize_cfg(params, cfg)
    indeg = dict(g.pred_n)
    level = {t: 0 for t in g.tasks}
    cur = sorted(t for t in g.tasks if indeg[t] == 0)
    levels: list[list[TaskId]] = []
    placed = 0
    while cur:
        levels.append(cur)
        placed += len(cur)
        nxt = set()
        for t in cur:
            for s in g.succ[t]:
                indeg[s] -= 1
                level[s] = max(level[s], level[t] + 1)
                if indeg[s] == 0:
                    nxt.add(s)
        cur = sorted(nxt)
    assert placed == len(g.tasks), "cycle in task graph"
    # re-bucket by longest-path level (Kahn order may under-level)
    buckets: dict[int, list[TaskId]] = {}
    for t, lv in level.items():
        buckets.setdefault(lv, []).append(t)
    levels = [sorted(buckets[lv]) for lv in sorted(buckets)]
    return WavefrontSchedule(levels, level)


def _level_array(ig: IndexedGraph) -> "np.ndarray":
    """Vectorized Kahn + longest-path over flat edge arrays.

    Each iteration retires one wavefront: the frontier's out-edges are
    gathered through a CSR index (ragged arange via repeat/cumsum), target
    levels max-propagate with ``np.maximum.at``, and in-degrees fall by
    per-target counts (``np.unique``).  The next frontier comes from the
    decremented targets only — O(V + E log E) total, never a full-array
    rescan per level.  Returns the longest-path level per global task id.
    """
    n = ig.n
    order = np.argsort(ig.edge_src, kind="stable")
    es = ig.edge_src[order]
    et = ig.edge_tgt[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(es, minlength=n), out=indptr[1:])
    indeg = ig.pred_n.copy()
    level = np.zeros(n, dtype=np.int64)
    frontier = np.flatnonzero(indeg == 0)
    done = 0
    while frontier.size:
        done += frontier.size
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        tot = int(counts.sum())
        if not tot:
            break
        csum = np.cumsum(counts)
        eidx = np.repeat(starts - (csum - counts), counts) + np.arange(tot, dtype=np.int64)
        tg = et[eidx]
        np.maximum.at(level, tg, np.repeat(level[frontier] + 1, counts))
        touched, dec = np.unique(tg, return_counts=True)
        indeg[touched] -= dec
        # a task enters the frontier exactly when its last get is satisfied
        frontier = touched[indeg[touched] == 0]
    assert done == n, "cycle in task graph"
    return level


def _synthesize_from_ig(ig: IndexedGraph) -> WavefrontSchedule:
    """Array-leveled schedule with TaskId labels (see :func:`_level_array`)."""
    lv = _level_array(ig).tolist()
    level_of = dict(zip(ig.tasks, lv))
    buckets: dict[int, list[TaskId]] = {}
    for t, l_ in zip(ig.tasks, lv):
        buckets.setdefault(l_, []).append(t)
    levels = [sorted(buckets[l_]) for l_ in sorted(buckets)]
    return WavefrontSchedule(levels, level_of)


def levels_from_array(level: "np.ndarray") -> list["np.ndarray"]:
    """Bucket global task ids by level with one stable argsort.

    ``level`` is an int array of per-task level indices (0-based, dense).
    Returns int64 id arrays per level with ids ascending within each —
    the exact :class:`IndexedSchedule.levels` layout.  Shared by
    :func:`synthesize_indexed` and the device executor
    (:mod:`repro.core.edt.device`) so both derive byte-identical frontiers
    from a ``level_of`` array.
    """
    if not level.size:
        return []
    order = np.argsort(level, kind="stable")   # ids ascend within a level
    bounds = np.cumsum(np.bincount(level))[:-1]
    return np.split(order, bounds)


def schedule_from_graph(ig: IndexedGraph) -> IndexedSchedule:
    """Level an already-materialized index graph (pure index space).

    The second half of :func:`synthesize_indexed`, split out so callers
    holding a cached :class:`IndexedGraph` (the graph cache, the schedule
    service) never re-materialize just to level.
    """
    level = _level_array(ig)
    return IndexedSchedule(levels=levels_from_array(level), level_of=level)


def synthesize_indexed(graph: TiledTaskGraph, params: dict, shards=UNSET,
                       parallel=UNSET, pool=UNSET, faults=UNSET,
                       recovery=UNSET, *, config=None,
                       session=None) -> tuple[IndexedGraph, IndexedSchedule]:
    """Level the graph without ever leaving index space.

    The sharded/million-task path: the (optionally sharded) index graph is
    leveled by :func:`_level_array` and bucketed with one stable argsort —
    no TaskId tuples, no per-task dicts.  Returns the graph too, since
    executors need the id -> label blocks only if they label at all.
    Knobs via ``config=``/``session=`` (session calls are cached — warm
    hits return the stored arrays); the per-call kwargs are deprecated.
    """
    cfg, sess = resolve_execution(
        config, session, stacklevel=3,
        legacy=dict(shards=shards, parallel=parallel, pool=pool,
                    faults=faults, recovery=recovery))
    if sess is not None:
        return sess.schedule(graph, params)
    ig = graph._index_graph_cfg(params, cfg)
    return ig, schedule_from_graph(ig)


def simulate_schedule(schedule: WavefrontSchedule, workers: int = 4,
                      task_dur: float = 1.0) -> Sim:
    """Execute a static wavefront schedule on the Sim, level by level.

    Each level is handed to the executor as ONE batch
    (:meth:`Sim.make_ready_batch`) — the on-device lowering where a whole
    wavefront launches together and the only sync is the level barrier.
    Returns the finished Sim (``exec_order``, ``counters.makespan``).
    """
    sim = Sim(workers, task_dur, setup_cost=0.0)

    def launch(i: int) -> None:
        if i >= len(schedule.levels):
            return
        lvl = schedule.levels[i]
        remaining = len(lvl)

        def done() -> None:
            nonlocal remaining
            remaining -= 1
            if remaining == 0:
                launch(i + 1)

        sim.make_ready_batch((t, done) for t in lvl)

    launch(0)
    sim.run()
    return sim


def simulate_indexed(schedule: IndexedSchedule, workers: int = 4,
                     task_dur: float = 1.0) -> Sim:
    """Execute an :class:`IndexedSchedule` level by level on the Sim.

    The array twin of :func:`simulate_schedule`: each level's id array is
    fed to the executor in one call (:meth:`Sim.make_ready_ids`) with a
    single shared completion callback — no per-task closures or labels, so
    the host-side cost of driving a merged million-task schedule is the
    queue itself.  ``exec_order`` holds global task ids.
    """
    sim = Sim(workers, task_dur, setup_cost=0.0)

    def launch(i: int) -> None:
        if i >= len(schedule.levels):
            return
        lvl = schedule.levels[i]
        state = {"remaining": int(lvl.size)}

        def done() -> None:
            state["remaining"] -= 1
            if state["remaining"] == 0:
                launch(i + 1)

        sim.make_ready_ids(lvl, done)

    launch(0)
    sim.run()
    return sim


def uniform_distance_vectors(graph: TiledTaskGraph) -> Optional[list[tuple]]:
    """If every tiled dependence is a constant shift T_t = T_s + d, return the
    distance vectors; else None.  (Pipelines and stencils are uniform.)"""
    out = []
    for td in graph.tiled_deps:
        ns = graph.tilings[td.dep.src].ndim
        nt = td.delta_t.ndim - ns
        if ns != nt or td.dep.src != td.dep.tgt:
            return None
        d = [None] * ns
        # look for equalities  T_t[i] - T_s[i] = d_i
        for e in td.delta_t.eqs:
            for i in range(ns):
                if (e[ns + i] != 0 and e[i] == -e[ns + i]
                        and all(e[j] == 0 for j in range(td.delta_t.ndim)
                                if j not in (i, ns + i))
                        and all(e[td.delta_t.ndim + p] == 0
                                for p in range(td.delta_t.nparam))):
                    d[i] = Fraction(e[-1], e[ns + i])
        if any(x is None for x in d):
            return None
        out.append(tuple(int(-x) if x == int(x) else None for x in d))
        if any(x is None for x in out[-1]):
            return None
    return out


def closed_form_level(graph: TiledTaskGraph) -> Optional[callable]:
    """For single-statement graphs with uniform nonnegative-lex distance
    vectors, the wavefront index is the classic hyperplane schedule
    t(T) = sum_i w_i T_i with w from the distances.  Returns a callable
    T -> level, or None when not applicable."""
    ds = uniform_distance_vectors(graph)
    if ds is None or not ds:
        return None
    # weights: smallest positive integer combination covering all distances;
    # use w_i = 1 when all distances are >= 0 and each has sum >= 1.
    if all(all(c >= 0 for c in d) and sum(d) >= 1 for d in ds):
        return lambda T: sum(T)
    return None
