"""Static wavefront schedule synthesis — the TPU-side realization of EDT.

XLA programs cannot spawn tasks dynamically, so on-device we resolve the
autodec counters *at compile time*: every task's earliest start level
(longest-path depth in the tile graph) becomes its wavefront index, and the
whole graph lowers to a loop over wavefronts in which all tasks of a level run
in parallel (data parallel across tiles / pipeline stages).  This is the
"overhead → 0" limit of the paper's Table 2: zero runtime sync objects,
because the dependence relation was exact at compile time.

For uniform dependences (constant distance vectors — pipelines, stencils) the
wavefront index also has a closed affine form; we derive it when possible so
huge tile spaces never need materializing.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Optional, Sequence

from .taskgraph import TaskId, TiledTaskGraph


@dataclass
class WavefrontSchedule:
    levels: list[list[TaskId]]
    level_of: dict[TaskId, int]

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def max_width(self) -> int:
        return max((len(l) for l in self.levels), default=0)

    def stats(self) -> dict:
        n = sum(len(l) for l in self.levels)
        return {"tasks": n, "depth": self.depth, "max_width": self.max_width,
                "avg_width": n / max(1, self.depth)}


def synthesize(graph: TiledTaskGraph, params: dict) -> WavefrontSchedule:
    """Longest-path leveling of the materialized tile graph."""
    g = graph.materialize(params)
    indeg = dict(g.pred_n)
    level = {t: 0 for t in g.tasks}
    cur = sorted(t for t in g.tasks if indeg[t] == 0)
    levels: list[list[TaskId]] = []
    placed = 0
    while cur:
        levels.append(cur)
        placed += len(cur)
        nxt = set()
        for t in cur:
            for s in g.succ[t]:
                indeg[s] -= 1
                level[s] = max(level[s], level[t] + 1)
                if indeg[s] == 0:
                    nxt.add(s)
        cur = sorted(nxt)
    assert placed == len(g.tasks), "cycle in task graph"
    # re-bucket by longest-path level (Kahn order may under-level)
    buckets: dict[int, list[TaskId]] = {}
    for t, l in level.items():
        buckets.setdefault(l, []).append(t)
    levels = [sorted(buckets[l]) for l in sorted(buckets)]
    return WavefrontSchedule(levels, level)


def uniform_distance_vectors(graph: TiledTaskGraph) -> Optional[list[tuple]]:
    """If every tiled dependence is a constant shift T_t = T_s + d, return the
    distance vectors; else None.  (Pipelines and stencils are uniform.)"""
    out = []
    for td in graph.tiled_deps:
        ns = graph.tilings[td.dep.src].ndim
        nt = td.delta_t.ndim - ns
        if ns != nt or td.dep.src != td.dep.tgt:
            return None
        d = [None] * ns
        # look for equalities  T_t[i] - T_s[i] = d_i
        for e in td.delta_t.eqs:
            for i in range(ns):
                if (e[ns + i] != 0 and e[i] == -e[ns + i]
                        and all(e[j] == 0 for j in range(td.delta_t.ndim)
                                if j not in (i, ns + i))
                        and all(e[td.delta_t.ndim + p] == 0
                                for p in range(td.delta_t.nparam))):
                    d[i] = Fraction(e[-1], e[ns + i])
        if any(x is None for x in d):
            return None
        out.append(tuple(int(-x) if x == int(x) else None for x in d))
        if any(x is None for x in out[-1]):
            return None
    return out


def closed_form_level(graph: TiledTaskGraph) -> Optional[callable]:
    """For single-statement graphs with uniform nonnegative-lex distance
    vectors, the wavefront index is the classic hyperplane schedule
    t(T) = sum_i w_i T_i with w from the distances.  Returns a callable
    T -> level, or None when not applicable."""
    ds = uniform_distance_vectors(graph)
    if ds is None or not ds:
        return None
    ndim = len(ds[0])
    # weights: smallest positive integer combination covering all distances;
    # use w_i = 1 when all distances are >= 0 and each has sum >= 1.
    if all(all(c >= 0 for c in d) and sum(d) >= 1 for d in ds):
        return lambda T: sum(T)
    return None
