"""A real-thread autodec runtime (the paper's §2.2.4, with preschedule).

Used two ways:
  * correctness evidence that the atomic get-or-create resolves the "who
    creates the successor" race (paper Fig 1) under genuine concurrency, and
  * as the host-side orchestration engine of the training runtime (data
    prefetch, async checkpoint, straggler backup tasks): dynamic events XLA
    cannot express.

The counter table is guarded by striped locks; `autodec` performs
get-or-create-then-decrement atomically, so exactly one caller observes the
transition to zero and becomes the task's (unique) creator.

Robustness (see ``docs/robustness.md``): a task body that raises does not
signal its successors, so its dependent cone never runs — the quarantine is
*structural*.  :func:`run_graph_threaded` surfaces every failure (an
aggregated :class:`~repro.core.edt.recovery.TaskGroupError`, not just the
first), a :class:`~repro.core.edt.recovery.Watchdog` converts hung bodies
and dropped decrements into :class:`StallReport`s with a counter-state
dump, and :func:`run_graph_threaded_resilient` returns the structured
:class:`FailureReport` (failed tasks, poisoned cone, undrained counters)
instead of raising.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Hashable, Iterable, Optional

from .faults import FaultPlan, InjectedTaskError
from .recovery import (FailureReport, StallError, StallReport, TaskGroupError,
                       Watchdog, cone_from_successors)

Key = Hashable


class ThreadedAutodec:
    """Autodec/preschedule over a task family given by three closures.

    pred_count(key) -> int           number of input dependences
    successors(key) -> iterable      keys to autodec at completion
    body(key) -> None                the task's computation
    """

    N_STRIPES = 64

    def __init__(self, pred_count: Callable[[Key], int],
                 successors: Callable[[Key], Iterable[Key]],
                 body: Callable[[Key], None],
                 workers: int = 4,
                 on_error: Optional[Callable[[Key, BaseException], None]] = None):
        self._pred_count = pred_count
        self._successors = successors
        self._body = body
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self._locks = [threading.Lock() for _ in range(self.N_STRIPES)]
        self._counters: dict[Key, int] = {}
        self._scheduled: set[Key] = set()
        self._executed: list[Key] = []
        self._exec_lock = threading.Lock()
        self._outstanding = 0
        self._quiet = threading.Condition()
        self._errors: list[tuple[Key, BaseException]] = []
        self._on_error = on_error
        # monotone progress counters for the stall watchdog
        self.started = 0
        self.finished = 0

    def _stripe(self, key: Key) -> threading.Lock:
        return self._locks[hash(key) % self.N_STRIPES]

    # ------------------------------------------------------------- protocol
    def _get_or_create_then(self, key: Key, decrement: bool) -> None:
        fire = False
        with self._stripe(key):
            if key in self._scheduled:
                # counter already consumed: a preschedule that arrives after
                # autodecs fired the task must not re-create it (that would
                # call pred_count twice and leak a dead counter entry)
                return
            if key not in self._counters:
                self._counters[key] = self._pred_count(key)
            if decrement:
                self._counters[key] -= 1
            if self._counters[key] <= 0:
                self._scheduled.add(key)
                del self._counters[key]  # GC at schedule time
                fire = True
        if fire:
            self._submit(key)

    def autodec(self, key: Key) -> None:
        self._get_or_create_then(key, decrement=True)

    def preschedule(self, key: Key) -> None:
        self._get_or_create_then(key, decrement=False)

    # ------------------------------------------------------------ execution
    def _submit(self, key: Key) -> None:
        with self._quiet:
            self._outstanding += 1
            self.started += 1
        self._pool.submit(self._run, key)

    def _run(self, key: Key) -> None:
        try:
            self._body(key)
            with self._exec_lock:
                self._executed.append(key)
            for s in self._successors(key):
                self.autodec(s)
        except BaseException as e:  # noqa: BLE001 — runtime must not wedge
            self._errors.append((key, e))
            if self._on_error:
                self._on_error(key, e)
        finally:
            with self._quiet:
                self._outstanding -= 1
                self.finished += 1
                if self._outstanding == 0:
                    self._quiet.notify_all()

    # -------------------------------------------------------------- control
    def preschedule_all(self, keys: Iterable[Key]) -> None:
        for k in keys:
            self.preschedule(k)

    def wait(self, timeout: Optional[float] = None) -> bool:
        with self._quiet:
            return self._quiet.wait_for(lambda: self._outstanding == 0, timeout)

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)

    @property
    def executed(self) -> list[Key]:
        return list(self._executed)

    @property
    def errors(self) -> list:
        return list(self._errors)

    # ---------------------------------------------------------- diagnostics
    def progress(self) -> tuple[int, int]:
        """Monotone ``(started, finished)`` for the stall watchdog."""
        with self._quiet:
            return self.started, self.finished

    def counter_snapshot(self) -> dict:
        """Undrained counters right now (diagnostic: racy by nature).

        Every key still present never reached zero — after quiescence this
        is exactly the set of tasks whose signals never arrived, with the
        remaining count each is waiting on.
        """
        return dict(self._counters)

    def failure_report(self, total: Optional[int] = None) -> Optional[FailureReport]:
        """Structured account of this run's failures (None when clean).

        The poisoned cone is the forward closure of the failed tasks over
        the ``successors`` closure — exactly the tasks whose counters can
        never drain because a failed body stopped signaling.
        """
        if not self._errors:
            return None
        failed = [k for k, _ in self._errors]
        cone = cone_from_successors(self._successors, failed)
        counters = self.counter_snapshot()
        return FailureReport(
            context="threaded",
            failed=[(k, repr(e)) for k, e in self._errors],
            poisoned=sorted(cone),
            undrained={k: c for k, c in counters.items() if k in cone},
            executed=len(self._executed),
            total=total)


@dataclass
class ThreadedRunResult:
    """Quarantined run outcome: what executed, plus structured diagnostics."""

    executed: list
    failure: Optional[FailureReport] = None
    stall: Optional[object] = None     # StallReport when progress died

    @property
    def ok(self) -> bool:
        return self.failure is None and self.stall is None


def _wrap_faulty_body(body: Callable, faults: FaultPlan) -> Callable:
    """Apply TASK_BODY_ERROR / WORKER_HANG faults around a task body."""
    import time as _time

    def run(key) -> None:
        hang = faults.hang_fault(key)
        if hang is not None:
            faults.record("worker_hang", key, 0)
            _time.sleep(hang.delay)
        fault = faults.body_fault(key)
        if fault is not None:
            faults.record("task_body_error", key, 0)
            raise InjectedTaskError(key)
        body(key)

    return run


def _wrap_faulty_successors(successors: Callable,
                            faults: FaultPlan) -> Callable:
    """Drop exactly one decrement into each DROPPED_DECREMENT target.

    The first signal headed for a dropped task is swallowed (atomically —
    producers race, but exactly one loses its signal); the task's counter
    can then never drain, which is precisely the deadlock the stall
    watchdog must convert into a report.
    """
    dropped = set(faults.dropped_tasks())
    lock = threading.Lock()

    def succ(key):
        for s in successors(key):
            if dropped:
                with lock:
                    if s in dropped:
                        dropped.discard(s)
                        faults.record("dropped_decrement", s, 0)
                        continue
            yield s

    return succ


def _execute_graph(graph, params: dict, workers: int, body, faults,
                   stall_timeout: float):
    """Shared driver: run the graph, watchdog the progress, diagnose.

    Returns ``(rt, total, stall_report)``.  Quiescence alone is not
    success: a dropped decrement leaves the runtime quiet with undrained
    counters, which is reported as a stall (the counter dump names the
    suspects) rather than silently returning a partial execution.
    """
    tasks = list(graph.tasks(params))
    run_body = body or (lambda t: None)
    successors = lambda t: list(graph.successors(t, params))  # noqa: E731
    if faults is not None:
        run_body = _wrap_faulty_body(run_body, faults)
        successors = _wrap_faulty_successors(successors, faults)
    rt = ThreadedAutodec(
        pred_count=lambda t: graph.pred_count(t, params),
        successors=successors,
        body=run_body,
        workers=workers,
    )
    dog = Watchdog(rt.progress, stall_timeout=stall_timeout,
                   context="threaded", dump=rt.counter_snapshot)
    stall = None
    with dog:
        rt.preschedule_all(tasks)
        while not rt.wait(timeout=min(0.05, stall_timeout / 4)):
            if dog.stalled.is_set():
                stall = dog.report
                break
    if stall is not None:
        rt.shutdown(wait=False)    # a hung body may never return
        return rt, len(tasks), stall
    rt.shutdown()
    # quiesced — but did every task run?  Tasks outside the poisoned cone
    # that never fired mean a decrement was dropped: a real deadlock.
    report = rt.failure_report(total=len(tasks))
    covered = len(rt.executed) + len(rt.errors)
    if report is not None:
        covered += len(report.poisoned)
    if covered < len(tasks):
        started, finished = rt.progress()
        stall = StallReport(
            context="threaded", elapsed=0.0,
            started=started, finished=finished, in_flight=0,
            undrained=rt.counter_snapshot(),
            note=(f"quiesced with {len(tasks) - covered} task(s) never "
                  "scheduled — a decrement was dropped"))
    return rt, len(tasks), stall


def run_graph_threaded(graph, params: dict, workers: int = 4,
                       body: Optional[Callable] = None,
                       faults: Optional[FaultPlan] = None,
                       stall_timeout: float = 300.0) -> list:
    """Execute a TiledTaskGraph with the threaded autodec runtime.

    Failures are aggregated: every (task key, exception) pair rides on one
    :class:`TaskGroupError` (with the :class:`FailureReport` attached)
    instead of surfacing only the first error.  A stall — hung body or
    dropped decrement — raises :class:`StallError` with the counter-state
    dump after ``stall_timeout`` seconds without progress.
    """
    rt, total, stall = _execute_graph(graph, params, workers, body, faults,
                                      stall_timeout)
    if stall is not None:
        raise StallError(stall)
    if rt.errors:
        raise TaskGroupError(rt.errors, rt.failure_report(total=total))
    return rt.executed


def run_graph_threaded_resilient(graph, params: dict, workers: int = 4,
                                 body: Optional[Callable] = None,
                                 faults: Optional[FaultPlan] = None,
                                 stall_timeout: float = 300.0) -> ThreadedRunResult:
    """Quarantined execution: never raises on task faults, always reports.

    A task-body exception cancels exactly its dependent cone (the other
    tasks run to completion) and the result carries the structured
    :class:`FailureReport`; a stall yields the :class:`StallReport`
    instead of a hang.  With no faults the executed list matches
    :func:`run_graph_threaded` exactly.
    """
    rt, total, stall = _execute_graph(graph, params, workers, body, faults,
                                      stall_timeout)
    return ThreadedRunResult(executed=rt.executed,
                             failure=rt.failure_report(total=total),
                             stall=stall)
