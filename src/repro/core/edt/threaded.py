"""A real-thread autodec runtime (the paper's §2.2.4, with preschedule).

Used two ways:
  * correctness evidence that the atomic get-or-create resolves the "who
    creates the successor" race (paper Fig 1) under genuine concurrency, and
  * as the host-side orchestration engine of the training runtime (data
    prefetch, async checkpoint, straggler backup tasks): dynamic events XLA
    cannot express.

The counter table is guarded by striped locks; `autodec` performs
get-or-create-then-decrement atomically, so exactly one caller observes the
transition to zero and becomes the task's (unique) creator.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Hashable, Iterable, Optional

Key = Hashable


class ThreadedAutodec:
    """Autodec/preschedule over a task family given by three closures.

    pred_count(key) -> int           number of input dependences
    successors(key) -> iterable      keys to autodec at completion
    body(key) -> None                the task's computation
    """

    N_STRIPES = 64

    def __init__(self, pred_count: Callable[[Key], int],
                 successors: Callable[[Key], Iterable[Key]],
                 body: Callable[[Key], None],
                 workers: int = 4,
                 on_error: Optional[Callable[[Key, BaseException], None]] = None):
        self._pred_count = pred_count
        self._successors = successors
        self._body = body
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self._locks = [threading.Lock() for _ in range(self.N_STRIPES)]
        self._counters: dict[Key, int] = {}
        self._scheduled: set[Key] = set()
        self._executed: list[Key] = []
        self._exec_lock = threading.Lock()
        self._outstanding = 0
        self._quiet = threading.Condition()
        self._errors: list[tuple[Key, BaseException]] = []
        self._on_error = on_error

    def _stripe(self, key: Key) -> threading.Lock:
        return self._locks[hash(key) % self.N_STRIPES]

    # ------------------------------------------------------------- protocol
    def _get_or_create_then(self, key: Key, decrement: bool) -> None:
        fire = False
        with self._stripe(key):
            if key in self._scheduled:
                # counter already consumed: a preschedule that arrives after
                # autodecs fired the task must not re-create it (that would
                # call pred_count twice and leak a dead counter entry)
                return
            if key not in self._counters:
                self._counters[key] = self._pred_count(key)
            if decrement:
                self._counters[key] -= 1
            if self._counters[key] <= 0:
                self._scheduled.add(key)
                del self._counters[key]  # GC at schedule time
                fire = True
        if fire:
            self._submit(key)

    def autodec(self, key: Key) -> None:
        self._get_or_create_then(key, decrement=True)

    def preschedule(self, key: Key) -> None:
        self._get_or_create_then(key, decrement=False)

    # ------------------------------------------------------------ execution
    def _submit(self, key: Key) -> None:
        with self._quiet:
            self._outstanding += 1
        self._pool.submit(self._run, key)

    def _run(self, key: Key) -> None:
        try:
            self._body(key)
            with self._exec_lock:
                self._executed.append(key)
            for s in self._successors(key):
                self.autodec(s)
        except BaseException as e:  # noqa: BLE001 — runtime must not wedge
            self._errors.append((key, e))
            if self._on_error:
                self._on_error(key, e)
        finally:
            with self._quiet:
                self._outstanding -= 1
                if self._outstanding == 0:
                    self._quiet.notify_all()

    # -------------------------------------------------------------- control
    def preschedule_all(self, keys: Iterable[Key]) -> None:
        for k in keys:
            self.preschedule(k)

    def wait(self, timeout: Optional[float] = None) -> bool:
        with self._quiet:
            return self._quiet.wait_for(lambda: self._outstanding == 0, timeout)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)

    @property
    def executed(self) -> list[Key]:
        return list(self._executed)

    @property
    def errors(self) -> list:
        return list(self._errors)


def run_graph_threaded(graph, params: dict, workers: int = 4,
                       body: Optional[Callable] = None) -> list:
    """Execute a TiledTaskGraph with the threaded autodec runtime."""
    done = body or (lambda t: None)
    rt = ThreadedAutodec(
        pred_count=lambda t: graph.pred_count(t, params),
        successors=lambda t: list(graph.successors(t, params)),
        body=done,
        workers=workers,
    )
    rt.preschedule_all(graph.tasks(params))
    ok = rt.wait(timeout=300)
    rt.shutdown()
    assert ok, "threaded autodec did not quiesce"
    if rt.errors:
        raise rt.errors[0][1]
    return rt.executed
