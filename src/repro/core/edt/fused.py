"""Fused device execution: task bodies inside the counted-sync sweep.

:class:`~repro.core.edt.device.DeviceExecutor` (PR 5) runs the §2 counted
synchronization model on device but computes nothing — the frontier math
is real, the tiles are phantoms.  This module closes the gap for the
stencil family: one jitted XLA program both decrements the counters
(keeping the transpose-CSR segment-sum decrement — XLA-CPU scatter-add
measured ~10x slower on million-edge graphs) **and** executes every tile
the frontier enables, so a ≥1M-task jacobi2d solve never returns to the
host between wavefronts.  That is the "A Tale of Three Runtimes" claim
made concrete: generated EDT code priced head-to-head against the
hand-written ``lax.fori_loop``/``lax.scan`` stencil of the same problem
(:func:`repro.kernels.stencils.handwritten_solve`,
``benchmarks/bench_fused.py``).

State layout
------------
The grid lives in one flat device vector ``u`` of ``2*S + 1`` elements
(``S = N^d`` sites):

* ``u[p*S + flat(site)]`` holds ``v_t[site]`` for time parity ``p = t & 1``
  (taps reach at most one step back, so two buffers suffice; the initial
  grid ``v_{-1}`` seeds parity 1),
* ``u[2*S]`` is a zero slot that every masked/out-of-range tap gathers
  from (the Dirichlet-0 halo),
* masked lanes *scatter* to index ``2*S + 1`` — out of bounds, dropped by
  ``mode="drop"`` — so padding never corrupts the halo zero.

Per level the sweep gathers the level's task ids (one fixed-width
``dynamic_slice``, exactly as the replay decrement does), looks up each
task's **tile origin** row (:func:`pack_origins` — tile coords × tile
sizes, with a sentinel row of negative time at index ``n`` that masks the
padded lanes), and runs the tile body: local offsets within a tile are a
*static* structure (``tt`` sequential over the tile's time extent — plus
sequential spatial dims for Gauss-Seidel — and the parallel spatial dims
vectorized), so each sub-step is a handful of fused gathers, a weighted
sum, and one scatter.  Site validity (``0 <= t < T`` and
``site ∈ [0, N)^d``) is exactly domain membership for the skewed stencil
programs, so partial tiles mask themselves.

Why same-level tiles never race: the EDT flow dependences of these
stencils cover every write-write and write-read hazard on the parity
buffers — a task overwriting slot ``(p, s)`` transitively depends on all
readers and the previous writer of that slot — so wavefront leveling
already linearizes conflicting accesses, and the per-level scatter indices
are distinct.  ``tests/test_fused_exec.py`` backs the argument with
bit-level oracles: :func:`host_execute` (the same level-major execution in
NumPy) equals the time-major :func:`~repro.kernels.stencils.reference_solve`
bitwise, and the device result matches both within documented tolerances.

Both sweep modes run fused: **replay** is the ``O(V+E)`` leveled
``fori_loop`` with the on-device schedule validation counters; **discover**
self-levels from the counters alone (``while_loop``, dense frontier — the
documented ``O(depth·V·g)`` test-scale tradeoff) with optional pallas
decrement.  Packed products (``DeviceGraph``, ``DeviceSchedule``, origin
columns) flow through :meth:`GraphCache.fused` / :meth:`Session.fused_packed`
so warm runs skip every host-side pack.  See ``docs/device_exec.md``
("Fused execution") for the measured numbers.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Union

import numpy as np

from ...kernels.stencils import SPECS, StencilSpec, default_state
from .config import resolve_execution
from .device import (DeviceCounters, _counter_summary, _diagnose_replay,
                     _step_xla, make_pallas_step, pack_graph, pack_schedule)
from .faults import DROPPED_DECREMENT
from .recovery import ScheduleValidationError, StallError, StallReport
from .taskgraph import IndexedGraph, TiledTaskGraph
from .wavefront import IndexedSchedule, levels_from_array

#: Sentinel origin row (index ``n``): a time coordinate this negative can
#: never satisfy ``t >= 0``, so padded lanes mask themselves.
SENTINEL_ORIGIN = -(1 << 20)


# ------------------------------------------------------------------ packing
def pack_origins(ig: IndexedGraph, tile) -> "np.ndarray":
    """Per-task tile-origin columns: ``i32[n + 1, ndim]``.

    Row ``t`` is task ``t``'s iteration-space origin (tile coordinates ×
    tile sizes, in the skewed program coordinates); the extra row at index
    ``n`` is the :data:`SENTINEL_ORIGIN` mask row the padded
    ``dynamic_slice`` lanes gather.
    """
    if len(ig.stmt_blocks) != 1:
        raise ValueError(
            "fused execution supports single-statement graphs; got "
            f"{len(ig.stmt_blocks)} statements")
    _, coords = ig.stmt_blocks[0]
    nd = coords.shape[1]
    sizes = np.asarray(tuple(tile), dtype=np.int64)
    if sizes.shape != (nd,):
        raise ValueError(
            f"tile sizes {tuple(tile)} do not match the graph's {nd} "
            "iteration dims")
    org = coords.astype(np.int64) * sizes
    if org.size and (int(org.max()) >= -SENTINEL_ORIGIN or int(org.min()) < 0):
        raise ValueError(
            "tile origins exceed the fused executor's index range")
    out = np.empty((ig.n + 1, nd), dtype=np.int32)
    out[:-1] = org
    out[-1] = SENTINEL_ORIGIN
    return out


def _local_steps(spec: StencilSpec, tile) -> list:
    """The tile body's static sub-step structure.

    Returns ``[(tt, loc), ...]``: for each sequential iteration (local
    time ``tt``, then any sequential spatial dims in lex order) the
    ``(sv, space)`` int32 matrix of vectorized local spatial offsets.
    Sub-steps execute in list order — the skewed lexicographic order the
    schedule requires.
    """
    gs = tile[1:]
    seq = [k for k in range(spec.space) if spec.seq_space[k]]
    par = [k for k in range(spec.space) if not spec.seq_space[k]]
    sv = 1
    for k in par:
        sv *= gs[k]
    base = np.zeros((sv, spec.space), np.int32)
    if par:
        grids = np.meshgrid(
            *[np.arange(gs[k], dtype=np.int32) for k in par], indexing="ij")
        for g, k in zip(grids, par):
            base[:, k] = g.ravel()
    steps = []
    for tt in range(tile[0]):
        for sq in itertools.product(*[range(gs[k]) for k in seq]):
            loc = base.copy()
            for k, v in zip(seq, sq):
                loc[:, k] = v
            steps.append((tt, loc))
    return steps


def _strides(space: int, extent: int) -> tuple:
    return tuple(extent ** (space - 1 - k) for k in range(space))


# --------------------------------------------------------------- host oracle
def host_execute(spec: StencilSpec, tile, steps: int, extent: int,
                 origins: "np.ndarray", levels, state: "np.ndarray"):
    """Level-major NumPy twin of the fused sweep (the host-dispatch path).

    Executes the same tiles in the same level order with the same masking
    — element for element the identical arithmetic — so it is bitwise
    equal to :func:`~repro.kernels.stencils.reference_solve` *and* serves
    as the host-dispatch baseline ``bench_fused.py`` prices.  Returns the
    final field ``v_{steps-1}``.
    """
    space = spec.space
    size = extent ** space
    st = np.asarray(_strides(space, extent), dtype=np.int64)
    u = np.zeros((2, size), dtype=state.dtype)
    u[1] = state.ravel()
    loc_steps = _local_steps(spec, tile)
    ty = state.dtype.type
    for ids in levels:
        org = origins[np.asarray(ids)].astype(np.int64)
        t0, osp = org[:, 0], org[:, 1:]
        for tt, loc in loc_steps:
            t = t0 + tt
            site = osp[:, None, :] + loc[None].astype(np.int64) \
                - t[:, None, None]
            ok0 = ((t >= 0) & (t < steps))[:, None] \
                & np.all((site >= 0) & (site < extent), axis=2)
            flat = site @ st
            pw = (t & 1)[:, None]
            acc = np.zeros(flat.shape, dtype=u.dtype)
            for dt, off, w in spec.taps:
                ok = ok0
                foff = 0
                for k, o in enumerate(off):
                    if o:
                        ns = site[..., k] + o
                        ok = ok & (ns >= 0) & (ns < extent)
                        foff += o * int(st[k])
                buf = np.broadcast_to(pw if dt == 0 else 1 - pw, ok.shape)
                vals = np.zeros(flat.shape, dtype=u.dtype)
                vals[ok] = u[buf[ok], (flat + foff)[ok]]
                acc = acc + ty(w) * vals
            pwb = np.broadcast_to(pw, ok0.shape)
            u[pwb[ok0], flat[ok0]] = acc[ok0]
    return u[(steps - 1) & 1].reshape(spec.shape(extent)).copy()


# ---------------------------------------------------------------------- run
@dataclass
class FusedRun:
    """One fused sweep: frontiers + counters + the computed grid.

    ``levels``/``level_of``/``counters`` mirror
    :class:`~repro.core.edt.device.DeviceRun` (byte-identical frontiers,
    same validation guarantees per mode); ``state`` is the full parity
    pair ``(2, N^d grid)`` and ``final`` the answer field ``v_{T-1}``.
    """

    mode: str                  # "discover" | "replay"
    levels: list
    level_of: "np.ndarray"
    counters: DeviceCounters
    state: "np.ndarray"        # (2,) + grid shape — both parity buffers
    final: "np.ndarray"        # grid shape — v_{steps-1}

    @property
    def exec_order(self) -> "np.ndarray":
        if not self.levels:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(self.levels)


class FusedExecutor:
    """End-to-end device-resident stencil execution of an EDT graph.

    Construct like :class:`~repro.core.edt.device.DeviceExecutor` — from a
    :class:`TiledTaskGraph` (``params`` required; ``config=``/``session=``
    drive generation, a session serves cached products) or an
    :class:`IndexedGraph` (then ``tile=`` names the tile sizes).  ``body``
    picks the :class:`~repro.kernels.stencils.StencilSpec` (a name from
    ``SPECS`` or a spec object); with a ``TiledTaskGraph`` it defaults to
    the program's registered name.  ``schedule=`` selects the O(V+E)
    replay sweep (validated on device unless ``validate=False`` drops the
    three violation counters from the compiled program); without it the
    discover sweep self-levels (``use_pallas=``/``interpret=`` as on the
    device executor).  ``packed=(DeviceGraph, DeviceSchedule | None,
    origins)`` skips all host-side packing — the graph cache's
    :meth:`~repro.core.edt.cache.GraphCache.fused` product plugs in here.

    ``state`` seeds the grid (default
    :func:`~repro.kernels.stencils.default_state`); ``dtype`` defaults to
    the state's (float64 requires x64 jax — use
    :func:`repro.compat.enable_x64`).  ``run()`` returns a
    :class:`FusedRun`; repeat runs (optionally with a fresh ``state=``)
    reuse the compiled sweep and pay dispatch cost only.
    """

    def __init__(self, graph: Union[TiledTaskGraph, IndexedGraph],
                 params: Optional[dict] = None, *,
                 body=None,
                 schedule: Optional[IndexedSchedule] = None,
                 state: Optional["np.ndarray"] = None,
                 dtype=None,
                 tile: Optional[tuple] = None,
                 validate: bool = True,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 config=None, session=None, packed=None):
        cfg, sess = resolve_execution(config, session, stacklevel=3)
        if isinstance(graph, TiledTaskGraph):
            if params is None:
                raise TypeError("params required with a TiledTaskGraph")
            ig = (sess.index_graph(graph, params) if sess is not None
                  else graph._index_graph_cfg(params, cfg))
            if tile is None:
                tile = graph_tile(graph)
            if body is None:
                body = getattr(graph.program, "name", "") or None
        else:
            ig = graph
            if tile is None:
                raise TypeError("tile= (tile sizes) required with an "
                                "IndexedGraph")
        if body is None:
            raise TypeError("body= required (a repro.kernels.stencils.SPECS "
                            "name or StencilSpec); TiledTaskGraph infers it "
                            "from the program name")
        if isinstance(body, StencilSpec):
            spec = body
        elif body in SPECS:
            spec = SPECS[body]
        else:
            raise TypeError(f"unknown stencil body {body!r}; known: "
                            f"{sorted(SPECS)}")
        if params is None:
            raise TypeError("params required (the spec's symbolic sizes)")
        tile = tuple(int(g) for g in tile)
        if len(tile) != spec.space + 1:
            raise ValueError(
                f"body {spec.name!r} needs {spec.space + 1} tile dims "
                f"(time + space); got {tile}")
        if ig.stmt_blocks and ig.stmt_blocks[0][1].shape[1] != len(tile):
            raise ValueError(
                f"graph has {ig.stmt_blocks[0][1].shape[1]} iteration dims, "
                f"tile names {len(tile)}")
        self.ig = ig
        self.spec = spec
        self.tile = tile
        self.steps = int(params[spec.time_param])
        self.extent = int(params[spec.size_param])
        self.size = self.extent ** spec.space
        if 2 * self.size + 2 >= np.iinfo(np.int32).max:
            raise ValueError(f"grid too large for int32 site indexing: "
                             f"{self.size} sites")
        self.faults = cfg.faults
        self.validate = bool(validate)
        if packed is not None and schedule is not None:
            raise TypeError("pass schedule= or packed=, not both")
        if use_pallas and (schedule is not None
                           or (packed is not None and packed[1] is not None)):
            raise TypeError(
                "use_pallas applies to the discover sweep only; drop "
                "schedule= to price the pallas decrement")
        if packed is not None:
            self.dg, self.ds, self.fo = packed
            if self.fo is None and self.ds is not None:
                self.fo = self.ds.origin
            if self.fo is None:
                self.fo = pack_origins(ig, tile)
        else:
            self.dg = pack_graph(ig)
            self.fo = pack_origins(ig, tile)
            self.ds = (pack_schedule(ig, schedule, origins=self.fo)
                       if schedule is not None else None)
        if dtype is None:
            dtype = state.dtype if state is not None else np.float32
        self.dtype = np.dtype(dtype)
        self._state = (default_state(spec, self.extent, self.dtype)
                       if state is None
                       else np.asarray(state, self.dtype))
        if self._state.shape != spec.shape(self.extent):
            raise ValueError(
                f"state shape {self._state.shape} != grid "
                f"{spec.shape(self.extent)}")
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._loc_steps = _local_steps(spec, tile)
        self._replay_fn = None
        self._discover_fn = None
        if use_pallas:
            self._pallas_step = make_pallas_step(
                self.dg.n, self.dg.n_edges, interpret)

    # ------------------------------------------------------------- plumbing
    def _check_x64(self):
        import jax

        if self.dtype == np.float64 and not jax.config.jax_enable_x64:
            raise RuntimeError(
                "float64 fused execution needs 64-bit jax types; wrap the "
                "run in repro.compat.enable_x64()")

    def _flat_state(self, a0: "np.ndarray") -> "np.ndarray":
        size = self.size
        u0 = np.zeros(2 * size + 1, dtype=self.dtype)
        u0[size:2 * size] = a0.ravel()   # v_{-1} lives in parity buffer 1
        return u0

    def _make_compute(self, jnp):
        """The tile body as traced XLA ops over one level's lanes.

        ``org`` is the ``(w, ndim)`` int32 origin rows (sentinel rows mask
        themselves through ``t < 0``); ``active`` optionally masks lanes
        (the discover frontier).  Sub-steps unroll statically; each is
        3^d masked gathers, a weighted sum, and one dropped-OOB scatter.
        """
        spec, steps, extent = self.spec, self.steps, self.extent
        size = self.size
        st = _strides(spec.space, extent)
        loc_steps = self._loc_steps
        taps = spec.taps

        def compute(u, org, active=None):
            t0 = org[:, 0]
            osp = org[:, 1:]
            for tt, loc in loc_steps:
                t = t0 + tt
                tmask = (t >= 0) & (t < steps)
                if active is not None:
                    tmask = tmask & active
                pw = (t & 1) * size
                site = (osp[:, None, :] + jnp.asarray(loc)[None]
                        - t[:, None, None])
                ok0 = tmask[:, None] & jnp.all(
                    (site >= 0) & (site < extent), axis=2)
                flat = site[..., 0] * st[0]
                for k in range(1, spec.space):
                    flat = flat + site[..., k] * st[k]
                acc = jnp.zeros(flat.shape, u.dtype)
                for dt, off, w in taps:
                    ok = ok0
                    foff = 0
                    for k, o in enumerate(off):
                        if o:
                            ns = site[..., k] + o
                            ok = ok & (ns >= 0) & (ns < extent)
                            foff += o * st[k]
                    base = pw if dt == 0 else size - pw
                    idx = jnp.where(ok, base[:, None] + flat + foff, 2 * size)
                    acc = acc + w * u[idx]
                widx = jnp.where(ok0, pw[:, None] + flat, 2 * size + 1)
                u = u.at[widx.reshape(-1)].set(acc.reshape(-1), mode="drop")
            return u

        return compute

    def _finish(self, mode, levels, level_of, counters, u) -> FusedRun:
        size = self.size
        grid = self.spec.shape(self.extent)
        state = u[:2 * size].reshape((2,) + grid)
        final = state[(self.steps - 1) & 1] if self.steps else self._state
        return FusedRun(mode, levels, level_of, counters, state, final)

    # --------------------------------------------------------------- sweeps
    def run(self, state: Optional["np.ndarray"] = None) -> FusedRun:
        a0 = (self._state if state is None
              else np.asarray(state, self.dtype))
        if a0.shape != self.spec.shape(self.extent):
            raise ValueError(f"state shape {a0.shape} != grid "
                             f"{self.spec.shape(self.extent)}")
        if self.dg.n == 0:
            counters = DeviceCounters(0, 0, 0, 0, np.zeros(0, np.int64))
            u = self._flat_state(a0)
            return self._finish(
                "replay" if self.ds is not None else "discover",
                [], np.zeros(0, np.int64), counters, u)
        self._check_x64()
        if self.ds is not None:
            return self._run_replay(a0)
        return self._run_discover(a0)

    def _build_replay(self):
        import jax
        import jax.numpy as jnp
        from jax import lax

        dg, ds = self.dg, self.ds
        n, depth, w_pad, e_pad = dg.n, ds.depth, ds.w_pad, ds.e_pad
        validate = self.validate
        op = jnp.asarray(ds.order)
        tp = jnp.asarray(ds.task_ptr)
        ep = jnp.asarray(ds.edge_ptr)
        tg = jnp.asarray(ds.lvl_tgt)
        org = jnp.asarray(self.fo)
        compute = self._make_compute(jnp)

        @jax.jit
        def sweep(indeg, u):
            aw = jnp.arange(w_pad, dtype=jnp.int32)
            ae = jnp.arange(e_pad, dtype=jnp.int32)

            def body(level, carry):
                indeg, u, not_ready, early, maxw = carry
                w = tp[level + 1] - tp[level]
                ids = lax.dynamic_slice(op, (tp[level],), (w_pad,))
                if validate:
                    # same three checks as the decrement-only replay sweep
                    not_ready += jnp.sum(
                        jnp.where(aw < w, indeg[ids] != 0, False),
                        dtype=jnp.int32)
                    nw = tp[level + 2] - tp[level + 1]
                    nids = lax.dynamic_slice(op, (tp[level + 1],), (w_pad,))
                    early += jnp.sum(
                        jnp.where(aw < nw, indeg[nids] == 0, False),
                        dtype=jnp.int32)
                # mask lanes past this level's width — the fixed-width id
                # slice spills into the next level's ids, not the sentinel
                u = compute(u, org[ids], active=aw < w)
                ec = ep[level + 1] - ep[level]
                tgts = lax.dynamic_slice(tg, (ep[level],), (e_pad,))
                tgts = jnp.where(ae < ec, tgts, n)
                indeg = indeg.at[tgts].add(-1)
                return indeg, u, not_ready, early, jnp.maximum(maxw, w)

            z = jnp.int32(0)
            indeg, u, not_ready, early, maxw = lax.fori_loop(
                0, depth, body, (indeg, u, z, z, z))
            undrained = (jnp.sum(indeg[:n] != 0, dtype=jnp.int32)
                         if validate else z)
            return not_ready, early, undrained, maxw, u

        return sweep

    def _run_replay(self, a0: "np.ndarray") -> FusedRun:
        import jax.numpy as jnp

        dg, ds = self.dg, self.ds
        if self._replay_fn is None:
            self._replay_fn = self._build_replay()
        indeg0 = jnp.concatenate([jnp.asarray(dg.pred_n),
                                  jnp.zeros(1, jnp.int32)])
        out = self._replay_fn(indeg0, jnp.asarray(self._flat_state(a0)))
        not_ready, early, undrained, maxw = (int(x) for x in out[:4])
        u = np.asarray(out[4])
        if not_ready or early or undrained:
            kind, level, ids, indeg = _diagnose_replay(dg, ds)
            counters = _counter_summary(indeg)
            counters.update(device_not_ready=not_ready, device_early=early,
                            device_undrained=undrained)
            raise ScheduleValidationError(kind, level, ids, counters)
        widths = np.asarray([lv.size for lv in ds.levels], dtype=np.int64)
        counters = DeviceCounters(dg.n, dg.n, maxw, ds.depth, widths)
        return self._finish("replay", ds.levels, ds.level_of, counters, u)

    def _run_discover(self, a0: "np.ndarray") -> FusedRun:
        import jax
        import jax.numpy as jnp

        dg = self.dg
        n = dg.n
        if self._discover_fn is None:
            step = (self._pallas_step if self.use_pallas else _step_xla(jnp))
            dec_src = jnp.asarray(dg.dec_src)
            dec_ptr = jnp.asarray(dg.dec_ptr)
            org = jnp.asarray(self.fo[:n])
            compute = self._make_compute(jnp)

            def cond(state):
                return state[1].any()

            def body(state):
                indeg, frontier, level, level_of, started, maxw, u = state
                w = frontier.sum().astype(jnp.int32)
                level_of = jnp.where(frontier, level, level_of)
                u = compute(u, org, active=frontier)
                indeg, newly = step(indeg, frontier, dec_src, dec_ptr)
                return (indeg, newly, level + 1, level_of, started + w,
                        jnp.maximum(maxw, w), u)

            self._discover_fn = jax.jit(
                lambda s: jax.lax.while_loop(cond, body, s))
        pred_host = dg.pred_n
        if self.faults is not None:
            dropped = [int(t) for t in self.faults.dropped_tasks()]
            if dropped:
                pred_host = pred_host.copy()
                for t in dropped:
                    pred_host[t] += 1
                    self.faults.record(DROPPED_DECREMENT, t, 0)
        pred = jnp.asarray(pred_host)
        init = (pred, pred == 0, jnp.int32(0), jnp.full(n, -1, jnp.int32),
                jnp.int32(0), jnp.int32(0),
                jnp.asarray(self._flat_state(a0)))
        out = self._discover_fn(init)
        indeg, depth, level_of, started, maxw = (
            np.asarray(out[i]) for i in (0, 2, 3, 4, 5))
        u = np.asarray(out[6])
        started = int(started)
        if started != n:
            und = np.flatnonzero(indeg != 0)
            report = StallReport(
                context="fused-discover", elapsed=0.0,
                started=started, finished=started, in_flight=0,
                undrained={int(t): int(indeg[t]) for t in und[:1024]},
                note=("fused counted-sync sweep reached a fixpoint with "
                      f"{und.size} counter(s) undrained — the task graph "
                      "has a cycle or a decrement was dropped"))
            raise StallError(report, msg=(
                f"fused counted-sync sweep deadlocked: {started}/{n} tasks "
                "became ready — the task graph has a cycle or a decrement "
                f"was dropped; undrained: {und[:8].tolist()}"
                + (f" (+{und.size - 8} more)" if und.size > 8 else "")))
        level_of = level_of.astype(np.int64)
        levels = levels_from_array(level_of)
        widths = np.asarray([lv.size for lv in levels], dtype=np.int64)
        counters = DeviceCounters(started, started, int(maxw), int(depth),
                                  widths)
        return self._finish("discover", levels, level_of, counters, u)


def graph_tile(graph: TiledTaskGraph) -> tuple:
    """The tile sizes of a single-statement graph (fused executor unit)."""
    if len(graph.tilings) != 1:
        raise ValueError("fused execution supports single-statement "
                         "programs; got "
                         f"{sorted(graph.tilings)}")
    (tiling,) = graph.tilings.values()
    return tuple(int(s) for s in tiling.sizes)
