"""A suite of polyhedral programs (paper §5 benchmark families).

Each builder returns a :class:`PolyhedralProgram` with symbolic size
parameters.  These cover the families the paper evaluates: stencils
(jacobi/seidel/heat), dense linear algebra (matmul, trisolv, LU-like
triangular loops), the diamond DAG of Fig 1/2 (single dominator — worst case
for prescribed synchronization), pipelines, and synthetic high-dimensional
codes that stress Fourier-Motzkin.
"""
from __future__ import annotations

from typing import Sequence

from .edt.taskgraph import PolyhedralProgram
from .poly import Polyhedron


def _product_domain(src: Polyhedron, tgt: Polyhedron,
                    src_suffix: str = "_s", tgt_suffix: str = "_t") -> Polyhedron:
    """Cartesian product src × tgt with renamed dims (shared params)."""
    assert src.param_names == tgt.param_names
    sd = tuple(n + src_suffix for n in src.dim_names)
    td = tuple(n + tgt_suffix for n in tgt.dim_names)
    a = src.rename(dim_names=sd).add_dims(td)
    b = tgt.rename(dim_names=td).add_dims(sd, front=True)
    return a.intersect(b.rename(dim_names=sd + td))


def dep(src: Polyhedron, tgt: Polyhedron, eqs: Sequence[Sequence[int]] = (),
        ineqs: Sequence[Sequence[int]] = ()) -> Polyhedron:
    """Dependence polyhedron over (src dims, tgt dims) with extra rows.

    Row layout: [src dims..., tgt dims..., params..., const].
    """
    d = _product_domain(src, tgt)
    for e in eqs:
        d = d.add_eq(e)
    for r in ineqs:
        d = d.add_ineq(r)
    return d


# ---------------------------------------------------------------- stencils
#
# Stencils are written in *schedule-transformed* (time-skewed) coordinates,
# exactly as the paper assumes (§3: "tiling is performed along scheduling
# hyperplanes" — orthogonal tiling is applied after the affine schedule).
# A raw symmetric stencil tiled orthogonally would yield a cyclic tile graph
# (illegal tiling); skewing x = i + t makes every dependence component
# non-negative so any orthogonal tiling is legal.

def stencil1d() -> PolyhedralProgram:
    """Jacobi-1D, skewed: (t,x) <- (t-1, x-2..x).  Params (T, N).

    Domain {(t,x) : 0<=t<T, t<=x<t+N} (x = i + t)."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("t", "x"), ("T", "N"),
        [(1, 0, 0, 0, 0), (-1, 0, 1, 0, -1),    # 0 <= t <= T-1
         (-1, 1, 0, 0, 0), (1, -1, 0, 1, -1)])  # t <= x <= t+N-1
    P.add_statement("S", D)
    delta = dep(D, D,
                eqs=[(1, 0, -1, 0, 0, 0, 1)],                    # t_t = t_s + 1
                ineqs=[(0, -1, 0, 1, 0, 0, 0),                   # x_t >= x_s
                       (0, 1, 0, -1, 0, 0, 2)])                  # x_t <= x_s + 2
    P.add_dependence("S", "S", delta, "jacobi1d")
    return P


def seidel1d() -> PolyhedralProgram:
    """Gauss-Seidel-1D, skewed (x = i + t): (t,x)->(t,x+1), (t,x)->(t+1,x)."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("t", "x"), ("T", "N"),
        [(1, 0, 0, 0, 0), (-1, 0, 1, 0, -1),
         (-1, 1, 0, 0, 0), (1, -1, 0, 1, -1)])
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[(1, 0, -1, 0, 0, 0, 0),
                                              (0, 1, 0, -1, 0, 0, 1)]),
                     "sweep")
    P.add_dependence("S", "S", dep(D, D, eqs=[(1, 0, -1, 0, 0, 0, 1),
                                              (0, 1, 0, -1, 0, 0, 0)]),
                     "carry")
    return P


def jacobi2d() -> PolyhedralProgram:
    """Jacobi-2D (9-point), skewed both space dims: offsets in {0,1,2}^2."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("t", "x", "y"), ("T", "N"),
        [(1, 0, 0, 0, 0, 0), (-1, 0, 0, 1, 0, -1),
         (-1, 1, 0, 0, 0, 0), (1, -1, 0, 0, 1, -1),
         (-1, 0, 1, 0, 0, 0), (1, 0, -1, 0, 1, -1)])
    P.add_statement("S", D)
    delta = dep(D, D,
                eqs=[(1, 0, 0, -1, 0, 0, 0, 0, 1)],
                ineqs=[(0, -1, 0, 0, 1, 0, 0, 0, 0),
                       (0, 1, 0, 0, -1, 0, 0, 0, 2),
                       (0, 0, -1, 0, 0, 1, 0, 0, 0),
                       (0, 0, 1, 0, 0, -1, 0, 0, 2)])
    P.add_dependence("S", "S", delta, "jacobi2d")
    return P


def heat3d() -> PolyhedralProgram:
    """Heat-3D (box stencil), skewed, 4 iteration dims — FM stress test."""
    P = PolyhedralProgram()
    rows = []
    nd, np_ = 4, 2  # (t,x,y,z), (T,N)
    # 0 <= t <= T-1
    lo = [0] * (nd + np_ + 1)
    lo[0] = 1
    hi = [0] * (nd + np_ + 1)
    hi[0], hi[nd], hi[-1] = -1, 1, -1
    rows += [lo, hi]
    for d in range(1, nd):
        lo = [0] * (nd + np_ + 1)
        lo[0], lo[d] = -1, 1            # x_d >= t
        hi = [0] * (nd + np_ + 1)
        hi[0], hi[d], hi[nd + 1], hi[-1] = 1, -1, 1, -1  # x_d <= t + N - 1
        rows += [lo, hi]
    D = Polyhedron.from_ineqs(("t", "x", "y", "z"), ("T", "N"), rows)
    P.add_statement("S", D)
    n2 = 2 * nd
    eq = [0] * (n2 + np_ + 1)
    eq[0], eq[nd], eq[-1] = 1, -1, 1          # t_t = t_s + 1
    ineqs = []
    for d in range(1, nd):
        r1 = [0] * (n2 + np_ + 1)
        r1[d], r1[nd + d] = -1, 1              # x_t >= x_s
        r2 = [0] * (n2 + np_ + 1)
        r2[d], r2[nd + d], r2[-1] = 1, -1, 2   # x_t <= x_s + 2
        ineqs += [r1, r2]
    P.add_dependence("S", "S", dep(D, D, eqs=[eq], ineqs=ineqs), "heat3d")
    return P


# ------------------------------------------------------------ linear algebra
def matmul() -> PolyhedralProgram:
    """Tiled C += A.B with the reduction loop kept sequential per (i,j).

    A task per (i,j,k) tile; dependence (i,j,k) -> (i,j,k+1) — the paper
    notes tasks are formed over all three loops for load balancing."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("i", "j", "k"), ("N",),
        [(1, 0, 0, 0, 0), (-1, 0, 0, 1, -1),
         (0, 1, 0, 0, 0), (0, -1, 0, 1, -1),
         (0, 0, 1, 0, 0), (0, 0, -1, 1, -1)])
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, 0, -1, 0, 0, 0, 0),
        (0, 1, 0, 0, -1, 0, 0, 0),
        (0, 0, 1, 0, 0, -1, 0, 1)]), "kred")
    return P


def trisolv() -> PolyhedralProgram:
    """Forward substitution: x_i -= L_ij x_j then divide.

    Domain {(i,j) : 0 <= j <= i < N}; deps:
      accumulate: (i,j) -> (i,j+1)   (j+1 <= i)
      broadcast:  (j,j) -> (i,j)     (i > j)  — x_j feeds every later row.
    Non-rectangular (triangular) — exercises the counting-loop strategy.
    """
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("i", "j"), ("N",),
        [(0, 1, 0, 0), (1, -1, 0, 0), (-1, 0, 1, -1)])  # 0<=j<=i<=N-1
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, -1, 0, 0, 0),         # i_t = i_s
        (0, 1, 0, -1, 0, 1)]),       # j_t = j_s + 1
        "accum")
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, -1, 0, 0, 0, 0),    # i_s = j_s   (the diagonal task)
             (0, 1, 0, -1, 0, 0)],   # j_t = j_s
        ineqs=[(-1, 0, 1, 0, 0, -1)]),  # i_t >= i_s + 1
        "bcast")
    return P


def cholesky_like() -> PolyhedralProgram:
    """Right-looking tiled Cholesky task DAG (à la TaskTorrent's benchmark).

    One statement S(k,i,j) over the prism {0 <= k <= j <= i <= N-1}; the
    role of a task is positional: (k,k,k) is POTRF(k), (k,i,k) with i>k is
    TRSM(k,i), and (k,i,j) with j>k is the SYRK/GEMM update of block (i,j)
    at step k.  Dependences:

      potrf_trsm: (k,k,k)   -> (k,i,k)    i>k   — the factored diagonal
                                                  feeds every panel solve
      upd_a:      (k,i,k)   -> (k,i,j)    j>k   — A(i,k) feeds row i updates
      upd_b:      (k,j,k)   -> (k,i,j)    i>j   — A(j,k) feeds column j
                                                  (strict: the diagonal SYRK
                                                  needs only upd_a)
      step:       (k,i,j)   -> (k+1,i,j)  j>k   — the updated block is the
                                                  step-(k+1) task on (i,j),
                                                  be it POTRF, TRSM, or GEMM

    Critical path Θ(N), wavefront width Θ(N²): the dense-LA shape whose
    frontier grows faster than the stencils' but slower than its task count
    — the interesting middle case for the sync-overhead atlas.
    """
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("k", "i", "j"), ("N",),
        [(1, 0, 0, 0, 0),        # k >= 0
         (-1, 0, 1, 0, 0),       # j >= k
         (0, 1, -1, 0, 0),       # i >= j
         (0, -1, 0, 1, -1)])     # i <= N-1
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, -1, 0, 0, 0, 0, 0, 0),      # i_s = k_s  (diagonal task)
             (1, 0, -1, 0, 0, 0, 0, 0),      # j_s = k_s
             (1, 0, 0, -1, 0, 0, 0, 0),      # k_t = k_s
             (1, 0, 0, 0, 0, -1, 0, 0)],     # j_t = k_s  (a panel solve)
        ineqs=[(-1, 0, 0, 0, 1, 0, 0, -1)]),  # i_t >= k_s + 1
        "potrf_trsm")
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, 0, -1, 0, 0, 0, 0, 0),      # j_s = k_s  (a panel solve)
             (1, 0, 0, -1, 0, 0, 0, 0),      # k_t = k_s
             (0, 1, 0, 0, -1, 0, 0, 0)],     # i_t = i_s  (same row)
        ineqs=[(-1, 0, 0, 0, 0, 1, 0, -1)]),  # j_t >= k_s + 1
        "upd_a")
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, 0, -1, 0, 0, 0, 0, 0),      # j_s = k_s  (a panel solve,
             (1, 0, 0, -1, 0, 0, 0, 0),      # k_t = k_s   strictly: i_s > k_s
             (0, 1, 0, 0, 0, -1, 0, 0)],     # j_t = i_s   so POTRF is excluded)
        ineqs=[(0, 0, 0, 0, 1, -1, 0, -1),    # i_t >= j_t + 1 (off-diagonal)
               (-1, 1, 0, 0, 0, 0, 0, -1)]),  # i_s >= k_s + 1
        "upd_b")
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, 0, 0, -1, 0, 0, 0, 1),      # k_t = k_s + 1
             (0, 1, 0, 0, -1, 0, 0, 0),      # i_t = i_s
             (0, 0, 1, 0, 0, -1, 0, 0)]),    # j_t = j_s (j_s > k_s implied
        "step")                              #   by the target domain)
    return P


def lu_like() -> PolyhedralProgram:
    """Right-looking update pattern: (k,i,j) <- (k-1,i,j), plus panel deps.

    Domain {(k,i,j): 0<=k<N, k<i<N... relaxed to k<=i,j<=N-1} — triangular in
    two dims; a heavier non-rectangular case."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("k", "i", "j"), ("N",),
        [(1, 0, 0, 0, 0), (-1, 1, 0, 0, 0), (-1, 0, 1, 0, 0),
         (0, -1, 0, 1, -1), (0, 0, -1, 1, -1)])
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, 0, -1, 0, 0, 0, 1),      # k_t = k_s + 1
        (0, 1, 0, 0, -1, 0, 0, 0),      # i_t = i_s
        (0, 0, 1, 0, 0, -1, 0, 0)]),    # j_t = j_s
        "update")
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, -1, 0, 0, 0, 0, 0, 0),   # i_s = k_s (panel row)
             (1, 0, 0, -1, 0, 0, 0, 0),   # k_t = k_s
             (0, 0, 1, 0, 0, -1, 0, 0)],  # j_t = j_s (same column)
        ineqs=[(0, -1, 0, 0, 1, 0, 0, -1)]),  # i_t > i_s
        "panel")
    return P


# ----------------------------------------------------------------- graphs
def diamond() -> PolyhedralProgram:
    """Grid DAG with right/down deps — single dominator at (0,0).

    The paper's worst case for prescribed Method 1 (Fig 1): the entire graph
    is dominated by one task, so the master must set up all O(n) tasks and
    O(n) edges before anything runs."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("i", "j"), ("K",),
        [(1, 0, 0, 0), (-1, 0, 1, -1), (0, 1, 0, 0), (0, -1, 1, -1)])
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, -1, 0, 0, 1), (0, 1, 0, -1, 0, 0)]), "down")
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, -1, 0, 0, 0), (0, 1, 0, -1, 0, 1)]), "right")
    return P


def pipeline() -> PolyhedralProgram:
    """(microbatch m, stage s) with deps (m,s)->(m,s+1) and (m,s)->(m+1,s).

    Exactly the pipeline-parallel training schedule; params (M, S)."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("m", "s"), ("M", "S"),
        [(1, 0, 0, 0, 0), (-1, 0, 1, 0, -1),
         (0, 1, 0, 0, 0), (0, -1, 0, 1, -1)])
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, -1, 0, 0, 0, 0), (0, 1, 0, -1, 0, 0, 1)]), "stage")
    P.add_dependence("S", "S", dep(D, D, eqs=[
        (1, 0, -1, 0, 0, 0, 1), (0, 1, 0, -1, 0, 0, 0)]), "next_mb")
    return P


def fanout_band(f: int) -> PolyhedralProgram:
    """Layered band DAG with constant per-task fan-out ~2f+1; params (L, W).

    Tasks (l, i) on an L×W grid; (l, i) feeds (l+1, j) for |j - i| <= f.
    Depth and wavefront width are *independent* parameters (depth L, width
    exactly W at every level) and the dependence fan-out is set by the
    compile-time band radius ``f`` — the atlas's knob for sweeping
    dependence fan-out and frontier width orthogonally (a banded stand-in
    for fan-out trees: affine, so the fan-out must be a constant, not a
    program parameter).

    Written skewed (x = i + f·l), like the stencils: the raw band has
    dependence components i_t - i_s < 0, so an orthogonal tiling with more
    than one layer per tile would produce a cyclic tile graph; skewing
    makes every component non-negative (0 <= x_t - x_s <= 2f) and any
    tiling legal.
    """
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("l", "x"), ("L", "W"),
        [(1, 0, 0, 0, 0), (-1, 0, 1, 0, -1),     # 0 <= l <= L-1
         (-f, 1, 0, 0, 0), (f, -1, 0, 1, -1)])   # f*l <= x <= f*l + W-1
    P.add_statement("S", D)
    P.add_dependence("S", "S", dep(
        D, D,
        eqs=[(1, 0, -1, 0, 0, 0, 1)],            # l_t = l_s + 1
        ineqs=[(0, -1, 0, 1, 0, 0, 0),           # x_t >= x_s
               (0, 1, 0, -1, 0, 0, 2 * f)]),     # x_t <= x_s + 2f
        f"band{f}")
    return P


def embarrassing() -> PolyhedralProgram:
    """No dependences at all (the 'embarrassingly parallel' control case)."""
    P = PolyhedralProgram()
    D = Polyhedron.from_ineqs(
        ("i",), ("N",), [(1, 0, 0), (-1, 1, -1)])
    P.add_statement("S", D)
    return P


def synthetic_highdim(nd: int = 5) -> PolyhedralProgram:
    """nd-dimensional box with a unit shift in every dim — FM stress test.

    The projection baseline must eliminate 2*nd dims from a 4*nd-dim system;
    compression never leaves dimension nd."""
    P = PolyhedralProgram()
    rows = []
    for d in range(nd):
        lo = [0] * (nd + 2)
        lo[d] = 1
        hi = [0] * (nd + 2)
        hi[d], hi[nd], hi[-1] = -1, 1, -1
        rows += [lo, hi]
    D = Polyhedron.from_ineqs(tuple(f"x{i}" for i in range(nd)), ("N",), rows)
    P.add_statement("S", D)
    n2 = 2 * nd
    eqs = []
    for d in range(nd):
        e = [0] * (n2 + 2)
        e[d], e[nd + d], e[-1] = 1, -1, 1   # x_t = x_s + 1 in every dim
        eqs.append(e)
    P.add_dependence("S", "S", dep(D, D, eqs=eqs), "shift")
    return P


def _named(name: str, build):
    """Stamp the registry key onto the built program (kept in one place so
    ``PolyhedralProgram.name`` can never drift from the PROGRAMS key —
    the fused executor resolves stencil bodies through it)."""
    def builder() -> PolyhedralProgram:
        p = build()
        p.name = name
        return p
    builder.__name__ = getattr(build, "__name__", name)
    return builder


PROGRAMS = {name: _named(name, fn) for name, fn in {
    "stencil1d": stencil1d,
    "seidel1d": seidel1d,
    "jacobi2d": jacobi2d,
    "heat3d": heat3d,
    "matmul": matmul,
    "trisolv": trisolv,
    "lu_like": lu_like,
    "cholesky_like": cholesky_like,
    "diamond": diamond,
    "fanout2": lambda: fanout_band(2),
    "fanout8": lambda: fanout_band(8),
    "pipeline": pipeline,
    "embarrassing": embarrassing,
    "synthetic5d": lambda: synthetic_highdim(5),
    "synthetic6d": lambda: synthetic_highdim(6),
}.items()}
